//! Dense factorization substrate (the LAPACK the paper's workers call).
//!
//! The image's PJRT CPU client cannot run LAPACK custom-calls lowered by
//! `jnp.linalg.*`, so the factorization kernels (QR for TSQR §8.3, Cholesky
//! and SPD solves for Newton §6) are implemented here from scratch and
//! exposed as block kernels through `runtime::native`.
//!
//! All routines are f64, row-major on [`Block`]s, and validated against
//! reconstruction/identity properties in the tests below plus property
//! suites in `rust/tests/prop_suites.rs`.

use crate::store::Block;

/// C = A · B (naive blocked i-k-j loop; the hot path for big blocks goes
/// through PJRT — this is the substrate/fallback).
pub fn matmul(a: &Block, b: &Block) -> Block {
    let (m, ka) = (a.rows(), a.cols());
    let (kb, n) = (b.rows(), b.cols());
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut out = vec![0.0; m * n];
    let (ab, bb) = (a.buf(), b.buf());
    for i in 0..m {
        let arow = &ab[i * ka..(i + 1) * ka];
        let orow = &mut out[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &bb[k * n..(k + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    Block::from_vec(&[m, n], out)
}

/// Thin (reduced) Householder QR: X[m,n] with m >= n -> (Q[m,n], R[n,n]),
/// R upper-triangular with non-negative diagonal (canonical form, so
/// TSQR trees produce comparable R factors).
pub fn householder_qr(x: &Block) -> (Block, Block) {
    let (m, n) = (x.rows(), x.cols());
    assert!(m >= n, "thin QR needs m >= n, got {m}x{n}");
    let mut r = x.buf().to_vec(); // working copy, becomes R in top n rows
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors

    for k in 0..n {
        // build v for column k below (and including) the diagonal
        let mut norm2 = 0.0;
        for i in k..m {
            let v = r[i * n + k];
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        let x0 = r[k * n + k];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m - k];
        if norm > 0.0 {
            v[0] = x0 - alpha;
            for i in (k + 1)..m {
                v[i - k] = r[i * n + k];
            }
            let vnorm2: f64 = v.iter().map(|t| t * t).sum();
            if vnorm2 > 0.0 {
                // apply H = I - 2 v v^T / (v^T v) to the trailing matrix
                for j in k..n {
                    let mut dot = 0.0;
                    for i in k..m {
                        dot += v[i - k] * r[i * n + j];
                    }
                    let scale = 2.0 * dot / vnorm2;
                    for i in k..m {
                        r[i * n + j] -= scale * v[i - k];
                    }
                }
            }
        }
        vs.push(v);
        // zero the column explicitly for numerical hygiene
        for i in (k + 1)..m {
            r[i * n + k] = 0.0;
        }
    }

    // sign-canonicalize: make diag(R) >= 0 by flipping rows of R (and the
    // corresponding columns of Q later via the flips vector)
    let mut flips = vec![1.0; n];
    for k in 0..n {
        if r[k * n + k] < 0.0 {
            flips[k] = -1.0;
            for j in k..n {
                r[k * n + j] = -r[k * n + j];
            }
        }
    }

    // form thin Q by applying the Householder reflectors to I[m,n]
    let mut q = vec![0.0; m * n];
    for (j, fj) in flips.iter().enumerate() {
        q[j * n + j] = *fj; // column j of (I * flip)
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|t| t * t).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let scale = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= scale * v[i - k];
            }
        }
    }

    let r_top = Block::from_vec(&[n, n], r[..n * n].to_vec());
    (Block::from_vec(&[m, n], q), r_top)
}

/// Cholesky factor L (lower) of an SPD matrix A = L Lᵀ.
pub fn cholesky(a: &Block) -> Block {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs square");
    let src = a.buf();
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = src[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(sum > 0.0, "matrix not positive definite at {i} (sum={sum})");
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Block::from_vec(&[n, n], l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Block, b: &Block) -> Block {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let m = b.cols();
    let (lb, bb) = (l.buf(), b.buf());
    let mut y = bb.to_vec();
    for c in 0..m {
        for i in 0..n {
            let mut v = y[i * m + c];
            for k in 0..i {
                v -= lb[i * n + k] * y[k * m + c];
            }
            y[i * m + c] = v / lb[i * n + i];
        }
    }
    Block::from_vec(&[n, m], y)
}

/// Solve U x = b (back substitution), U upper-triangular.
pub fn solve_upper(u: &Block, b: &Block) -> Block {
    let n = u.rows();
    assert_eq!(b.rows(), n);
    let m = b.cols();
    let (ub, bb) = (u.buf(), b.buf());
    let mut x = bb.to_vec();
    for c in 0..m {
        for i in (0..n).rev() {
            let mut v = x[i * m + c];
            for k in (i + 1)..n {
                v -= ub[i * n + k] * x[k * m + c];
            }
            x[i * m + c] = v / ub[i * n + i];
        }
    }
    Block::from_vec(&[n, m], x)
}

/// Solve the SPD system A x = b via Cholesky (the Newton step H⁻¹g, §6).
/// A tiny ridge keeps near-singular Hessians factorable, matching the
/// Python reference (`model.newton_solve_ref`).
pub fn solve_spd(a: &Block, b: &Block, ridge: f64) -> Block {
    let n = a.rows();
    let mut a2 = a.clone();
    for i in 0..n {
        let v = a2.at2(i, i) + ridge;
        a2.set2(i, i, v);
    }
    let l = cholesky(&a2);
    let y = solve_lower(&l, b);
    // L^T x = y: solve with U = L^T
    solve_upper(&l.transposed(), &y)
}

/// Inverse of an upper-triangular matrix (indirect TSQR's R⁻¹, §8.3).
pub fn inv_upper(u: &Block) -> Block {
    let n = u.rows();
    assert_eq!(n, u.cols());
    let mut eye = Block::zeros(&[n, n]);
    for i in 0..n {
        eye.set2(i, i, 1.0);
    }
    solve_upper(u, &eye)
}

/// Frobenius norm.
pub fn fro_norm(a: &Block) -> f64 {
    a.buf().iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Identity block.
pub fn eye(n: usize) -> Block {
    let mut b = Block::zeros(&[n, n]);
    for i in 0..n {
        b.set2(i, i, 1.0);
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(shape: &[usize], seed: u64) -> Block {
        let mut rng = Rng::seed_from_u64(seed);
        let n: usize = shape.iter().product();
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        Block::from_vec(shape, v)
    }

    #[test]
    fn matmul_identity() {
        let a = randn(&[5, 5], 1);
        assert!(matmul(&a, &eye(5)).max_abs_diff(&a) < 1e-12);
        assert!(matmul(&eye(5), &a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn qr_reconstructs() {
        for (m, n, seed) in [(8, 8, 2), (20, 5, 3), (64, 16, 4), (5, 1, 5)] {
            let x = randn(&[m, n], seed);
            let (q, r) = householder_qr(&x);
            assert_eq!(q.shape, vec![m, n]);
            assert_eq!(r.shape, vec![n, n]);
            let back = matmul(&q, &r);
            assert!(back.max_abs_diff(&x) < 1e-10, "reconstruction {m}x{n}");
            // orthonormal columns
            let qtq = matmul(&q.transposed(), &q);
            assert!(qtq.max_abs_diff(&eye(n)) < 1e-10, "Q^T Q != I");
            // upper-triangular with non-negative diagonal
            for i in 0..n {
                assert!(r.at2(i, i) >= 0.0);
                for j in 0..i {
                    assert!(r.at2(i, j).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let x = randn(&[12, 6], 7);
        let a = matmul(&x.transposed(), &x); // SPD (whp)
        let l = cholesky(&a);
        assert!(matmul(&l, &l.transposed()).max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn spd_solve_matches_direct() {
        let x = randn(&[20, 5], 8);
        let a = matmul(&x.transposed(), &x);
        let b = randn(&[5, 2], 9);
        let sol = solve_spd(&a, &b, 0.0);
        assert!(matmul(&a, &sol).max_abs_diff(&b) < 1e-8);
    }

    #[test]
    fn inv_upper_is_inverse() {
        let x = randn(&[10, 4], 10);
        let (_, r) = householder_qr(&x);
        let rinv = inv_upper(&r);
        assert!(matmul(&r, &rinv).max_abs_diff(&eye(4)) < 1e-9);
    }

    #[test]
    fn triangular_solves() {
        let x = randn(&[6, 6], 11);
        let a = matmul(&x.transposed(), &x);
        let l = cholesky(&a);
        let b = randn(&[6, 1], 12);
        let y = solve_lower(&l, &b);
        assert!(matmul(&l, &y).max_abs_diff(&b) < 1e-10);
        let z = solve_upper(&l.transposed(), &y);
        assert!(matmul(&a, &z).max_abs_diff(&b) < 1e-8);
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn cholesky_rejects_indefinite() {
        let mut a = eye(3);
        a.set2(2, 2, -1.0);
        cholesky(&a);
    }
}
