//! Packed-panel SIMD microkernels — the [`crate::runtime::KernelTier::Simd`]
//! implementations behind `dense::matmul_tier` / `dense::gram_tier` and the
//! fused element-wise interpreter.
//!
//! # Packing layout
//!
//! The contraction kernels follow the GotoBLAS panel decomposition. For
//! each KC-deep panel of the contraction dimension, both operands are
//! copied once into contiguous pool-backed buffers
//! ([`crate::store::block::pool`]), then every register tile streams from
//! those packs:
//!
//! * **A pack** — MR-interleaved row strips: strip `s` holds rows
//!   `[s·MR, s·MR + mr)` as `apack[dk·mr + r]`, so the microkernel
//!   broadcasts `mr` consecutive values per k-step from one cache line.
//! * **B pack** — NR-contiguous column tiles: tile `t` holds columns
//!   `[t·NR, t·NR + nr)` as `bpack[dk·nr + u]`, so each k-step loads two
//!   `__m256d` vectors from consecutive addresses (unaligned loads; the
//!   pool's `Vec<f64>` is 8-byte aligned).
//!
//! The register tile is MR×NR = 4×8: eight `__m256d` accumulators (half
//! the AVX2 register file), two B loads and four A broadcasts per k-step,
//! each feeding two `_mm256_fmadd_pd`.
//!
//! # Determinism policy
//!
//! Results must not depend on the thread split or on whether a row/column
//! lands in a full or an edge tile. Every output element is therefore
//! computed with the **identical** operation sequence: per KC panel, a
//! local accumulator starts at zero and FMAs `a·b` in ascending-k order,
//! then folds into C (`c += acc`, or `c = α·(c + acc)` on the final
//! panel). The scalar edge path uses [`f64::mul_add`] — the same IEEE
//! fused multiply-add the vector lanes execute — so edge tiles are
//! bit-identical to full tiles and thread counts never change bits.
//!
//! What *does* change relative to the Scalar tier is FMA contraction (one
//! rounding per multiply-add instead of two) and the per-panel
//! accumulation grouping; the epsilon suite in `tests/kernel_tier.rs`
//! bounds that error explicitly. The SIMD contraction path also assumes
//! finite inputs: it does not replicate the scalar tier's zero-skip
//! (which exists to keep `0·inf` out of the blocked kernel's oracle
//! identity).
//!
//! The element-wise segment ops at the bottom are deliberately FMA-free:
//! `_mm256_add_pd`-family instructions are per-lane IEEE identical to the
//! scalar expressions, so fused-vs-unfused bit-identity holds in both
//! tiers.

use crate::runtime::kernel::BinOp;
use crate::store::block::pool;
use crate::store::Block;

use super::dense::{div_up, kernel_threads};

/// Register-tile rows (A-side broadcast count per k-step).
pub(crate) const MR: usize = 4;
/// Register-tile columns (two `__m256d` of f64 lanes).
pub(crate) const NR: usize = 8;
/// Panel depth kept hot across a strip sweep (matches `dense::KC`).
const KC: usize = 256;
/// Panel width packed per B sweep (matches `dense::NC`).
const NC: usize = 512;

// --------------------------------------------------------------- matmul

/// `α · (A[m,k] @ B[k,n])` via the packed-panel FMA microkernel, with the
/// scale epilogue applied during the final panel's C-writeback (no
/// separate pass over the output). Parallel over disjoint row ranges;
/// bit-stable across thread counts (see module docs).
pub fn matmul_packed(a: &Block, b: &Block, alpha: f64, budget: usize) -> Block {
    let (m, ka) = (a.rows(), a.cols());
    let (kb, n) = (b.rows(), b.cols());
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut out = pool::alloc_zeroed(m * n);
    if m == 0 || n == 0 {
        return Block::from_vec(&[m, n], out);
    }
    if ka == 0 {
        // no panels run, but the epilogue still applies: α·0 keeps the
        // sign semantics of an unfused Scale pass over zeros
        scale_sweep(&mut out, alpha);
        return Block::from_vec(&[m, n], out);
    }
    let (ab, bb) = (a.buf(), b.buf());
    let threads = kernel_threads(2.0 * m as f64 * ka as f64 * n as f64, m, budget);
    if threads <= 1 {
        packed_rows(ab, bb, &mut out, 0, m, ka, n, alpha);
    } else {
        let rows_per = div_up(m, threads);
        std::thread::scope(|scope| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let r0 = t * rows_per;
                let r1 = r0 + chunk.len() / n;
                scope.spawn(move || packed_rows(ab, bb, chunk, r0, r1, ka, n, alpha));
            }
        });
    }
    Block::from_vec(&[m, n], out)
}

/// One thread's share of the packed matmul: absolute rows `[r0, r1)`,
/// `c` holding exactly those rows.
fn packed_rows(
    ab: &[f64],
    bb: &[f64],
    c: &mut [f64],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    alpha: f64,
) {
    let rows = r1 - r0;
    let kc_max = KC.min(k);
    let mut apack = pool::alloc_zeroed(rows * kc_max);
    let mut bpack = pool::alloc_zeroed(kc_max * div_up(NC.min(n), NR) * NR);
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let kc = kend - kk;
        let last = kend == k;
        // pack A: MR-interleaved strips for rows [r0, r1)
        let mut off = 0;
        let mut i = r0;
        while i < r1 {
            let mr = MR.min(r1 - i);
            for dk in 0..kc {
                for r in 0..mr {
                    apack[off + dk * mr + r] = ab[(i + r) * k + kk + dk];
                }
            }
            off += mr * kc;
            i += mr;
        }
        let mut jj = 0;
        while jj < n {
            let jend = (jj + NC).min(n);
            pack_b_tiles(bb, &mut bpack, kk, kc, jj, jend, n);
            sweep_panel(kc, &apack, rows, &bpack, jj, jend, c, n, alpha, last);
            jj = jend;
        }
        kk = kend;
    }
    pool::recycle(apack);
    pool::recycle(bpack);
}

/// Pack B rows `[kk, kk+kc)` × columns `[jj, jend)` into NR-contiguous
/// column tiles (`bpack[tile][dk·nr + u]`).
fn pack_b_tiles(
    bb: &[f64],
    bpack: &mut [f64],
    kk: usize,
    kc: usize,
    jj: usize,
    jend: usize,
    n: usize,
) {
    let mut off = 0;
    let mut j = jj;
    while j < jend {
        let nr = NR.min(jend - j);
        for dk in 0..kc {
            let src = (kk + dk) * n + j;
            bpack[off + dk * nr..off + dk * nr + nr].copy_from_slice(&bb[src..src + nr]);
        }
        off += nr * kc;
        j += nr;
    }
}

/// Sweep every packed A strip against every packed B tile of one
/// (panel, jj-block), folding accumulators into `c` (row stride `n`,
/// row 0 of the strips at `c[0]`).
#[allow(clippy::too_many_arguments)]
fn sweep_panel(
    kc: usize,
    apack: &[f64],
    rows: usize,
    bpack: &[f64],
    jj: usize,
    jend: usize,
    c: &mut [f64],
    n: usize,
    alpha: f64,
    last: bool,
) {
    let mut aoff = 0;
    let mut i = 0;
    while i < rows {
        let mr = MR.min(rows - i);
        let mut boff = 0;
        let mut j = jj;
        while j < jend {
            let nr = NR.min(jend - j);
            let ctile = &mut c[i * n + j..];
            if mr == MR && nr == NR {
                full_tile(kc, &apack[aoff..aoff + MR * kc], &bpack[boff..boff + NR * kc], ctile, n, alpha, last);
            } else {
                mk_edge(kc, &apack[aoff..aoff + mr * kc], &bpack[boff..boff + nr * kc], mr, nr, ctile, n, alpha, last);
            }
            boff += nr * kc;
            j += nr;
        }
        aoff += mr * kc;
        i += mr;
    }
}

// ----------------------------------------------------------------- gram

/// `α · (A[m,p]ᵀ @ B[m,q])` through the same packed-panel path — the Aᵀ
/// strips are copied straight out of A's rows (`mr` *contiguous* values
/// per k-step), replacing the strided per-tile re-reads of the streaming
/// scalar kernel. Parallel over disjoint ranges of the contraction
/// dimension with a deterministic in-order partial reduction, like
/// `dense::gram_with`.
pub fn gram_packed(a: &Block, b: &Block, alpha: f64, budget: usize) -> Block {
    let (m, p) = (a.rows(), a.cols());
    let (m2, q) = (b.rows(), b.cols());
    assert_eq!(m, m2, "gram {:?}ᵀ x {:?}", a.shape, b.shape);
    let (ab, bb) = (a.buf(), b.buf());
    let threads = kernel_threads(2.0 * m as f64 * p as f64 * q as f64, m, budget);
    if threads <= 1 {
        let mut out = pool::alloc_zeroed(p * q);
        gram_range(ab, bb, &mut out, 0, m, p, q, alpha);
        return Block::from_vec(&[p, q], out);
    }
    let rows_per = div_up(m, threads);
    let partials: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r0 = t * rows_per;
                let r1 = ((t + 1) * rows_per).min(m);
                scope.spawn(move || {
                    let mut part = pool::alloc_zeroed(p * q);
                    gram_range(ab, bb, &mut part, r0, r1, p, q, 1.0);
                    part
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut out = pool::alloc_zeroed(p * q);
    for part in partials {
        for (o, v) in out.iter_mut().zip(&part) {
            *o += *v;
        }
        pool::recycle(part);
    }
    // α is one multiply of the final sum — the same single rounding the
    // serial path applies on its last panel's writeback
    scale_sweep(&mut out, alpha);
    Block::from_vec(&[p, q], out)
}

/// Packed gram over contraction rows `[m0, m1)`, accumulating into the
/// full `p×q` buffer `out`.
#[allow(clippy::too_many_arguments)]
fn gram_range(
    ab: &[f64],
    bb: &[f64],
    out: &mut [f64],
    m0: usize,
    m1: usize,
    p: usize,
    q: usize,
    alpha: f64,
) {
    if m1 == m0 || p == 0 || q == 0 {
        scale_sweep(out, alpha);
        return;
    }
    let kc_max = KC.min(m1 - m0);
    let mut apack = pool::alloc_zeroed(p * kc_max);
    let mut bpack = pool::alloc_zeroed(kc_max * div_up(NC.min(q), NR) * NR);
    let mut i0 = m0;
    while i0 < m1 {
        let iend = (i0 + KC).min(m1);
        let kc = iend - i0;
        let last = iend == m1;
        // pack Aᵀ strips: contiguous copies from A's rows, no strides
        let mut off = 0;
        let mut x = 0;
        while x < p {
            let mr = MR.min(p - x);
            for dk in 0..kc {
                let src = (i0 + dk) * p + x;
                apack[off + dk * mr..off + dk * mr + mr].copy_from_slice(&ab[src..src + mr]);
            }
            off += mr * kc;
            x += mr;
        }
        let mut jj = 0;
        while jj < q {
            let jend = (jj + NC).min(q);
            pack_b_tiles(bb, &mut bpack, i0, kc, jj, jend, q);
            sweep_panel(kc, &apack, p, &bpack, jj, jend, out, q, alpha, last);
            jj = jend;
        }
        i0 = iend;
    }
    pool::recycle(apack);
    pool::recycle(bpack);
}

/// `out *= α` (skipped when α = 1): the epilogue applied as a sweep where
/// no panel writeback ran. `α·v` is exactly what a separate `Scale` task
/// computes, so folded epilogues stay bit-identical to unfused ones.
fn scale_sweep(out: &mut [f64], alpha: f64) {
    if alpha != 1.0 {
        for v in out.iter_mut() {
            *v *= alpha;
        }
    }
}

// ------------------------------------------------------ register tiles

/// Full MR×NR tile on AVX2+FMA.
///
/// Safety wrapper: the Simd tier only exists after `KernelTier::detect()`
/// (or `simd_if_available()`) confirmed AVX2+FMA on this host.
#[cfg(target_arch = "x86_64")]
#[inline]
fn full_tile(kc: usize, ap: &[f64], bp: &[f64], c: &mut [f64], n: usize, alpha: f64, last: bool) {
    unsafe { mk4x8(kc, ap.as_ptr(), bp.as_ptr(), c.as_mut_ptr(), n, alpha, last) }
}

/// Portable full tile: identical operation sequence via `f64::mul_add`.
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn full_tile(kc: usize, ap: &[f64], bp: &[f64], c: &mut [f64], n: usize, alpha: f64, last: bool) {
    mk_edge(kc, ap, bp, MR, NR, c, n, alpha, last);
}

/// The 4×8 f64 register tile: 8 ymm accumulators over one KC panel.
/// Writeback folds into C, applying the α epilogue on the final panel —
/// `c = α·(c + acc)`, float-identical to a separate Scale pass over the
/// finished output.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mk4x8(
    kc: usize,
    ap: *const f64,
    bp: *const f64,
    c: *mut f64,
    n: usize,
    alpha: f64,
    last: bool,
) {
    use core::arch::x86_64::*;
    let mut acc00 = _mm256_setzero_pd();
    let mut acc01 = _mm256_setzero_pd();
    let mut acc10 = _mm256_setzero_pd();
    let mut acc11 = _mm256_setzero_pd();
    let mut acc20 = _mm256_setzero_pd();
    let mut acc21 = _mm256_setzero_pd();
    let mut acc30 = _mm256_setzero_pd();
    let mut acc31 = _mm256_setzero_pd();
    for dk in 0..kc {
        let b0 = _mm256_loadu_pd(bp.add(dk * NR));
        let b1 = _mm256_loadu_pd(bp.add(dk * NR + 4));
        let a0 = _mm256_set1_pd(*ap.add(dk * MR));
        acc00 = _mm256_fmadd_pd(a0, b0, acc00);
        acc01 = _mm256_fmadd_pd(a0, b1, acc01);
        let a1 = _mm256_set1_pd(*ap.add(dk * MR + 1));
        acc10 = _mm256_fmadd_pd(a1, b0, acc10);
        acc11 = _mm256_fmadd_pd(a1, b1, acc11);
        let a2 = _mm256_set1_pd(*ap.add(dk * MR + 2));
        acc20 = _mm256_fmadd_pd(a2, b0, acc20);
        acc21 = _mm256_fmadd_pd(a2, b1, acc21);
        let a3 = _mm256_set1_pd(*ap.add(dk * MR + 3));
        acc30 = _mm256_fmadd_pd(a3, b0, acc30);
        acc31 = _mm256_fmadd_pd(a3, b1, acc31);
    }
    let accs = [
        [acc00, acc01],
        [acc10, acc11],
        [acc20, acc21],
        [acc30, acc31],
    ];
    if last && alpha != 1.0 {
        let av = _mm256_set1_pd(alpha);
        for (r, pair) in accs.iter().enumerate() {
            for (h, &acc) in pair.iter().enumerate() {
                let p = c.add(r * n + h * 4);
                let cur = _mm256_loadu_pd(p);
                _mm256_storeu_pd(p, _mm256_mul_pd(av, _mm256_add_pd(cur, acc)));
            }
        }
    } else {
        for (r, pair) in accs.iter().enumerate() {
            for (h, &acc) in pair.iter().enumerate() {
                let p = c.add(r * n + h * 4);
                let cur = _mm256_loadu_pd(p);
                _mm256_storeu_pd(p, _mm256_add_pd(cur, acc));
            }
        }
    }
}

/// Scalar twin of the vector tile for edge strips/tiles (`mr < MR` or
/// `nr < NR`) — same packed operands, same per-element sequence:
/// [`f64::mul_add`] is the same IEEE fused multiply-add the vector lanes
/// execute, so a row's bits never depend on which tile shape the thread
/// split put it in.
#[allow(clippy::too_many_arguments)]
fn mk_edge(
    kc: usize,
    ap: &[f64],
    bp: &[f64],
    mr: usize,
    nr: usize,
    c: &mut [f64],
    n: usize,
    alpha: f64,
    last: bool,
) {
    for r in 0..mr {
        for u in 0..nr {
            let mut acc = 0.0f64;
            for dk in 0..kc {
                acc = ap[dk * mr + r].mul_add(bp[dk * nr + u], acc);
            }
            let cv = &mut c[r * n + u];
            if last && alpha != 1.0 {
                *cv = alpha * (*cv + acc);
            } else {
                *cv += acc;
            }
        }
    }
}

// ----------------------------------------------- element-wise segments

/// Lane-exact AVX2 negate: a sign-bit flip (`xor` with -0.0), exactly the
/// scalar `-v` (note `0.0 - v` would get `-0.0` wrong).
pub(crate) fn neg_segment(seg: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        neg_avx2(seg);
    }
    #[cfg(not(target_arch = "x86_64"))]
    for v in seg {
        *v = -*v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn neg_avx2(seg: &mut [f64]) {
    use core::arch::x86_64::*;
    let mask = _mm256_set1_pd(-0.0);
    let p = seg.as_mut_ptr();
    let lanes = seg.len() / 4 * 4;
    let mut i = 0;
    while i < lanes {
        _mm256_storeu_pd(p.add(i), _mm256_xor_pd(_mm256_loadu_pd(p.add(i)), mask));
        i += 4;
    }
    for v in &mut seg[lanes..] {
        *v = -*v;
    }
}

/// Lane-exact AVX2 scale: per-lane `c·v`, the scalar expression exactly.
pub(crate) fn scale_segment(seg: &mut [f64], c: f64) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        scale_avx2(seg, c);
    }
    #[cfg(not(target_arch = "x86_64"))]
    for v in seg {
        *v = c * *v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_avx2(seg: &mut [f64], c: f64) {
    use core::arch::x86_64::*;
    let cv = _mm256_set1_pd(c);
    let p = seg.as_mut_ptr();
    let lanes = seg.len() / 4 * 4;
    let mut i = 0;
    while i < lanes {
        _mm256_storeu_pd(p.add(i), _mm256_mul_pd(cv, _mm256_loadu_pd(p.add(i))));
        i += 4;
    }
    for v in &mut seg[lanes..] {
        *v = c * *v;
    }
}

/// Lane-exact AVX2 binary segment: `acc ∘= rhs` (operands swapped when
/// `rev`). Add/Sub/Mul/Div are per-lane IEEE operations — no FMA — so the
/// Simd tier changes no bits in element-wise kernels and the
/// fused-vs-unfused identity suites hold unchanged.
pub(crate) fn bin_segment_simd(acc: &mut [f64], rhs: &[f64], op: BinOp, rev: bool) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        bin_avx2(acc, rhs, op, rev);
    }
    #[cfg(not(target_arch = "x86_64"))]
    for (a, &b) in acc.iter_mut().zip(rhs) {
        let (x, y) = if rev { (b, *a) } else { (*a, b) };
        *a = match op {
            BinOp::Add => x + y,
            BinOp::Sub => x - y,
            BinOp::Mul => x * y,
            BinOp::Div => x / y,
        };
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bin_avx2(acc: &mut [f64], rhs: &[f64], op: BinOp, rev: bool) {
    use core::arch::x86_64::*;
    let pa = acc.as_mut_ptr();
    let pb = rhs.as_ptr();
    let lanes = acc.len().min(rhs.len()) / 4 * 4;
    let mut i = 0;
    while i < lanes {
        let a = _mm256_loadu_pd(pa.add(i));
        let b = _mm256_loadu_pd(pb.add(i));
        let (x, y) = if rev { (b, a) } else { (a, b) };
        let r = match op {
            BinOp::Add => _mm256_add_pd(x, y),
            BinOp::Sub => _mm256_sub_pd(x, y),
            BinOp::Mul => _mm256_mul_pd(x, y),
            BinOp::Div => _mm256_div_pd(x, y),
        };
        _mm256_storeu_pd(pa.add(i), r);
        i += 4;
    }
    for (a, &b) in acc[lanes..].iter_mut().zip(&rhs[lanes..]) {
        let (x, y) = if rev { (b, *a) } else { (*a, b) };
        *a = match op {
            BinOp::Add => x + y,
            BinOp::Sub => x - y,
            BinOp::Mul => x * y,
            BinOp::Div => x / y,
        };
    }
}

// --------------------------------------------------- GLM inner kernels

/// FMA dot product (the GLM `xᵀβ` row kernel): 4-wide fused
/// multiply-adds, a fixed-order horizontal reduction, and an FMA scalar
/// tail. Deterministic (single code path), epsilon-close to the scalar
/// accumulation.
pub(crate) fn dot_fma(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    unsafe {
        dot_avx2(a, b)
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let mut acc = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            acc = x.mul_add(*y, acc);
        }
        acc
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len().min(b.len());
    let lanes = n / 4 * 4;
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_pd();
    let mut i = 0;
    while i < lanes {
        acc = _mm256_fmadd_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)), acc);
        i += 4;
    }
    let mut l = [0.0f64; 4];
    _mm256_storeu_pd(l.as_mut_ptr(), acc);
    let mut s = (l[0] + l[1]) + (l[2] + l[3]);
    for j in lanes..n {
        s = a[j].mul_add(b[j], s);
    }
    s
}

/// FMA axpy (`y += a·x`) — the GLM gradient/Hessian row update.
pub(crate) fn axpy_fma(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    unsafe {
        axpy_avx2(y, a, x);
    }
    #[cfg(not(target_arch = "x86_64"))]
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv = a.mul_add(xv, *yv);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(y: &mut [f64], a: f64, x: &[f64]) {
    use core::arch::x86_64::*;
    let n = y.len().min(x.len());
    let lanes = n / 4 * 4;
    let av = _mm256_set1_pd(a);
    let (py, px) = (y.as_mut_ptr(), x.as_ptr());
    let mut i = 0;
    while i < lanes {
        let r = _mm256_fmadd_pd(av, _mm256_loadu_pd(px.add(i)), _mm256_loadu_pd(py.add(i)));
        _mm256_storeu_pd(py.add(i), r);
        i += 4;
    }
    for j in lanes..n {
        y[j] = a.mul_add(x[j], y[j]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense;
    use crate::util::rng::Rng;

    fn randn(shape: &[usize], seed: u64) -> Block {
        let mut rng = Rng::seed_from_u64(seed);
        let n: usize = shape.iter().product();
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        Block::from_vec(shape, v)
    }

    /// Per-element relative bound for an FMA-reordered k-term
    /// contraction: `C·k·ε · (|A|·|B|)[i,j]` plus a tiny absolute floor.
    fn contraction_bound(aabs: &Block, babs: &Block, k: usize) -> Block {
        let mut mag = dense::matmul_naive(aabs, babs);
        let c = 4.0 * k as f64 * f64::EPSILON;
        for v in mag.buf_mut() {
            *v = *v * c + 1e-300;
        }
        mag
    }

    fn assert_close(got: &Block, want: &Block, bound: &Block, ctx: &str) {
        for ((g, w), b) in got.buf().iter().zip(want.buf()).zip(bound.buf()) {
            assert!(
                (g - w).abs() <= *b,
                "{ctx}: |{g} - {w}| = {} > bound {b}",
                (g - w).abs()
            );
        }
    }

    #[test]
    fn packed_matches_naive_within_fma_bound() {
        // odd/prime/degenerate shapes: every edge-strip and edge-tile
        // path, plus k crossing the KC panel boundary
        for (m, k, n, seed) in [
            (1, 1, 1, 50),
            (1, 37, 1, 51),
            (7, 11, 13, 52),
            (4, 256, 8, 53),
            (5, 300, 9, 54),
            (64, 64, 64, 55),
            (65, 257, 33, 56),
        ] {
            let a = randn(&[m, k], seed);
            let b = randn(&[k, n], seed + 500);
            let got = matmul_packed(&a, &b, 1.0, 1);
            let want = dense::matmul_naive(&a, &b);
            let aabs = Block::from_vec(&[m, k], a.buf().iter().map(|v| v.abs()).collect());
            let babs = Block::from_vec(&[k, n], b.buf().iter().map(|v| v.abs()).collect());
            let bound = contraction_bound(&aabs, &babs, k);
            assert_close(&got, &want, &bound, &format!("packed {m}x{k}x{n}"));
        }
    }

    #[test]
    fn packed_is_bit_stable_across_thread_budgets() {
        // the determinism contract: thread splits move rows between full
        // and edge strips, but the per-element FMA sequence is identical
        // either way, so bits must not change. 2·400·300·200 = 4.8e7
        // FLOPs sits above PAR_THRESHOLD, so the budgets really thread.
        let a = randn(&[400, 300], 62);
        let b = randn(&[300, 200], 63);
        let one = matmul_packed(&a, &b, 1.0, 1);
        for budget in [2, 3, 5, 8] {
            let t = matmul_packed(&a, &b, 1.0, budget);
            assert_eq!(
                one.max_abs_diff(&t),
                0.0,
                "thread budget {budget} changed bits"
            );
        }
    }

    #[test]
    fn alpha_epilogue_equals_separate_scale_pass() {
        let a = randn(&[33, 47], 64);
        let b = randn(&[47, 21], 65);
        for alpha in [2.5, -1.0, 0.0] {
            let fused = matmul_packed(&a, &b, alpha, 1);
            let mut separate = matmul_packed(&a, &b, 1.0, 1);
            for v in separate.buf_mut() {
                *v *= alpha;
            }
            assert_eq!(
                fused.max_abs_diff(&separate),
                0.0,
                "α={alpha} writeback must be float-identical to a Scale pass"
            );
        }
    }

    #[test]
    fn zero_k_matmul_applies_alpha_to_zeros() {
        let a = Block::zeros(&[2, 0]);
        let b = Block::zeros(&[0, 3]);
        let c = matmul_packed(&a, &b, -2.0, 1);
        assert_eq!(c.shape, vec![2, 3]);
        assert!(c.buf().iter().all(|&v| v == 0.0)); // -0.0 == 0.0
    }

    #[test]
    fn gram_packed_matches_transpose_matmul() {
        for (m, p, q, seed) in [(1, 1, 1, 70), (40, 7, 9, 71), (300, 5, 6, 72), (257, 17, 11, 73)] {
            let x = randn(&[m, p], seed);
            let y = randn(&[m, q], seed + 500);
            let got = gram_packed(&x, &y, 1.0, 1);
            let want = dense::matmul_naive(&x.transposed(), &y);
            let xabs = Block::from_vec(&[p, m], x.transposed().buf().iter().map(|v| v.abs()).collect());
            let yabs = Block::from_vec(&[m, q], y.buf().iter().map(|v| v.abs()).collect());
            let bound = contraction_bound(&xabs, &yabs, m);
            assert_close(&got, &want, &bound, &format!("gram {m}x{p}x{q}"));
        }
    }

    #[test]
    fn gram_packed_self_product_is_exactly_symmetric() {
        // (x,y) and (y,x) run the same i-ascending FMA sequence with the
        // same panel grouping, and f64 multiplication commutes — so
        // Xᵀ·X symmetry is exact, not approximate, in the packed path too
        // 2·25000·26² = 3.4e7 FLOPs > PAR_THRESHOLD: budget 4 really
        // threads, so the partial reduction is covered too
        for budget in [1, 4] {
            let x = randn(&[25000, 26], 74);
            let g = gram_packed(&x, &x, 1.0, budget);
            for i in 0..26 {
                for j in 0..26 {
                    assert_eq!(
                        g.at2(i, j),
                        g.at2(j, i),
                        "gram symmetry must be exact at ({i},{j}), budget {budget}"
                    );
                }
            }
        }
    }

    #[test]
    fn ew_segments_match_scalar_bits() {
        let mut rng = Rng::seed_from_u64(80);
        let mut a = vec![0.0; 1027]; // odd length: exercises the lane tail
        let mut b = vec![0.0; 1027];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);

        let mut neg = a.clone();
        neg_segment(&mut neg);
        for (g, v) in neg.iter().zip(&a) {
            assert_eq!(*g, -*v);
        }
        // sign-flip exactness on zeros (0.0 - v would get this wrong)
        let mut z = vec![0.0, -0.0];
        neg_segment(&mut z);
        assert!(z[0].is_sign_negative() && z[1].is_sign_positive());

        let mut sc = a.clone();
        scale_segment(&mut sc, 3.25);
        for (g, v) in sc.iter().zip(&a) {
            assert_eq!(*g, 3.25 * *v);
        }

        for op in [BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Div] {
            for rev in [false, true] {
                let mut acc = a.clone();
                bin_segment_simd(&mut acc, &b, op, rev);
                for ((g, &x), &y) in acc.iter().zip(&a).zip(&b) {
                    let (l, r) = if rev { (y, x) } else { (x, y) };
                    let want = match op {
                        BinOp::Add => l + r,
                        BinOp::Sub => l - r,
                        BinOp::Mul => l * r,
                        BinOp::Div => l / r,
                    };
                    assert!(
                        (*g == want) || (g.is_nan() && want.is_nan()),
                        "{op:?} rev={rev}: {g} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn dot_and_axpy_are_epsilon_close_to_scalar() {
        let mut rng = Rng::seed_from_u64(81);
        let mut a = vec![0.0; 133];
        let mut b = vec![0.0; 133];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let scalar: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let mag: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        let got = dot_fma(&a, &b);
        assert!((got - scalar).abs() <= 4.0 * 133.0 * f64::EPSILON * mag + 1e-300);

        let mut y = b.clone();
        axpy_fma(&mut y, 0.75, &a);
        for ((g, &x), &y0) in y.iter().zip(&a).zip(&b) {
            let want = 0.75 * x + y0;
            assert!((g - want).abs() <= 4.0 * f64::EPSILON * (want.abs() + 1.0));
        }
    }
}
