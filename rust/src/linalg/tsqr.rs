//! Distributed tall-skinny QR (§8.3).
//!
//! * **Direct TSQR** (Benson–Gleich–Demmel [5]): per-block thin QR, a
//!   binary tree of `StackQr` over the R factors, then Q recovered by
//!   propagating the tree's Q-factor halves back down
//!   (`Q_i = Q_i⁰ · Π_level Split{Top,Bottom}(Q^level)`).
//! * **Indirect TSQR** (Constantine–Gleich [12], Spark MLlib's variant):
//!   the same R tree (Q factors discarded), then `Q = X R⁻¹`.
//!
//! Both build one expression graph so LSHS sees the whole computation; the
//! tree reduction inherits the locality-aware pairing that makes local
//! stacks free.

use anyhow::Result;

use crate::api::{RunReport, Session};
use crate::graph::vertex::Ref;
use crate::graph::{DistArray, Graph};
use crate::grid::ArrayGrid;
use crate::runtime::kernel::Kernel;

pub struct QrResult {
    /// Row-partitioned Q [n, d] with X's grid.
    pub q: DistArray,
    /// Single-block R [d, d].
    pub r: DistArray,
    pub report: RunReport,
}

/// Direct TSQR: returns (Q, R) with Q explicitly formed.
pub fn direct_tsqr(sess: &mut Session, x: &DistArray) -> Result<QrResult> {
    assert_eq!(x.grid.grid[1], 1, "TSQR wants a row-partitioned tall matrix");
    let d = x.grid.shape[1];
    let q_blocks = x.grid.grid[0];
    let mut g = Graph::new();

    // level 0: thin QR per block
    let mut level: Vec<(Ref, Vec<Ref>)> = Vec::with_capacity(q_blocks);
    // (R ref, per-original-block factor path) — paths[i] collects the
    // [d,d] factors to right-multiply into block i's Q.
    let mut paths: Vec<Vec<Ref>> = vec![Vec::new(); q_blocks];
    let mut q0: Vec<Ref> = Vec::with_capacity(q_blocks);
    let mut owners: Vec<Vec<usize>> = Vec::with_capacity(q_blocks);
    for i in 0..q_blocks {
        let shape = x.grid.block_shape(&[i, 0]);
        let leaf = g.leaf(x.obj_at(&[i, 0]), &shape);
        let qr = g.op(Kernel::Qr, vec![(leaf, 0)]);
        q0.push((qr, 0));
        level.push(((qr, 1), Vec::new()));
        owners.push(vec![i]);
    }

    // binary tree over R factors
    while level.len() > 1 {
        let mut next: Vec<(Ref, Vec<Ref>)> = Vec::new();
        let mut next_owners: Vec<Vec<usize>> = Vec::new();
        let mut it = 0;
        while it + 1 < level.len() {
            let (ra, _) = level[it].clone();
            let (rb, _) = level[it + 1].clone();
            let sqr = g.op(Kernel::StackQr, vec![ra, rb]);
            let top = g.op(Kernel::SplitTop, vec![(sqr, 0)]);
            let bot = g.op(Kernel::SplitBottom, vec![(sqr, 0)]);
            for &blk in &owners[it] {
                paths[blk].push((top, 0));
            }
            for &blk in &owners[it + 1] {
                paths[blk].push((bot, 0));
            }
            let merged: Vec<usize> = owners[it]
                .iter()
                .chain(owners[it + 1].iter())
                .cloned()
                .collect();
            next.push(((sqr, 1), Vec::new()));
            next_owners.push(merged);
            it += 2;
        }
        if it < level.len() {
            next.push(level[it].clone());
            next_owners.push(owners[it].clone());
        }
        level = next;
        owners = next_owners;
    }
    let r_root = level[0].0;

    // back-propagate: Q_i = Q_i^0 · path factors (in level order)
    let q_roots: Vec<Ref> = (0..q_blocks)
        .map(|i| {
            let mut acc = q0[i];
            for &f in &paths[i] {
                acc = (g.op(Kernel::Matmul, vec![acc, f]), 0);
            }
            acc
        })
        .collect();

    let q_grid = ArrayGrid::new(&[x.grid.shape[0], d], &[q_blocks, 1]);
    let q_out = g.add_output(q_grid, q_roots);
    let r_out = g.add_output(ArrayGrid::new(&[d, d], &[1, 1]), vec![r_root]);

    let (outs, report) = sess.run(&mut g)?;
    Ok(QrResult {
        q: outs[q_out].clone(),
        r: outs[r_out].clone(),
        report,
    })
}

/// Indirect TSQR: R from the tree, Q = X R⁻¹.
pub fn indirect_tsqr(sess: &mut Session, x: &DistArray) -> Result<QrResult> {
    assert_eq!(x.grid.grid[1], 1, "TSQR wants a row-partitioned tall matrix");
    let d = x.grid.shape[1];
    let q_blocks = x.grid.grid[0];
    let mut g = Graph::new();

    // R-only tree
    let mut level: Vec<Ref> = (0..q_blocks)
        .map(|i| {
            let shape = x.grid.block_shape(&[i, 0]);
            let leaf = g.leaf(x.obj_at(&[i, 0]), &shape);
            (g.op(Kernel::Qr, vec![(leaf, 0)]), 1) // keep R, drop Q
        })
        .collect();
    while level.len() > 1 {
        let mut next = Vec::new();
        let mut it = 0;
        while it + 1 < level.len() {
            let sqr = g.op(Kernel::StackQr, vec![level[it], level[it + 1]]);
            next.push((sqr, 1));
            it += 2;
        }
        if it < level.len() {
            next.push(level[it]);
        }
        level = next;
    }
    let r_root = level[0];
    let rinv = g.op(Kernel::InvUpper, vec![r_root]);

    // Q_i = X_i @ R^{-1}
    let q_roots: Vec<Ref> = (0..q_blocks)
        .map(|i| {
            let shape = x.grid.block_shape(&[i, 0]);
            let leaf = g.leaf(x.obj_at(&[i, 0]), &shape);
            (g.op(Kernel::Matmul, vec![(leaf, 0), (rinv, 0)]), 0)
        })
        .collect();

    let q_grid = ArrayGrid::new(&[x.grid.shape[0], d], &[q_blocks, 1]);
    let q_out = g.add_output(q_grid, q_roots);
    let r_out = g.add_output(ArrayGrid::new(&[d, d], &[1, 1]), vec![r_root]);

    let (outs, report) = sess.run(&mut g)?;
    Ok(QrResult {
        q: outs[q_out].clone(),
        r: outs[r_out].clone(),
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionConfig;
    use crate::linalg::dense;

    fn check_qr(sess: &Session, x: &DistArray, res: &QrResult, tol: f64) {
        let xd = sess.fetch(x).unwrap();
        let qd = sess.fetch(&res.q).unwrap();
        let rd = sess.fetch(&res.r).unwrap();
        // reconstruction
        let back = dense::matmul(&qd, &rd);
        assert!(back.max_abs_diff(&xd) < tol, "QR != X");
        // orthonormality
        let qtq = dense::matmul(&qd.transposed(), &qd);
        let d = rd.rows();
        assert!(qtq.max_abs_diff(&dense::eye(d)) < tol, "QᵀQ != I");
        // R upper-triangular
        for i in 0..d {
            for j in 0..i {
                assert!(rd.at2(i, j).abs() < tol);
            }
        }
    }

    #[test]
    fn direct_tsqr_correct() {
        for q in [1usize, 2, 3, 4, 7] {
            let mut sess = Session::new(SessionConfig::real_small(2, 2));
            let x = sess.randn(&[64 * q, 8], &[q, 1]);
            let res = direct_tsqr(&mut sess, &x).unwrap();
            check_qr(&sess, &x, &res, 1e-9);
        }
    }

    #[test]
    fn indirect_tsqr_correct() {
        for q in [1usize, 2, 5, 8] {
            let mut sess = Session::new(SessionConfig::real_small(2, 2));
            let x = sess.randn(&[32 * q, 6], &[q, 1]);
            let res = indirect_tsqr(&mut sess, &x).unwrap();
            check_qr(&sess, &x, &res, 1e-8);
        }
    }

    #[test]
    fn direct_and_indirect_agree_on_r() {
        let mut s1 = Session::new(SessionConfig::real_small(2, 2));
        let x1 = s1.randn(&[128, 4], &[4, 1]);
        let r1 = direct_tsqr(&mut s1, &x1).unwrap();
        let mut s2 = Session::new(SessionConfig::real_small(2, 2));
        let x2 = s2.randn(&[128, 4], &[4, 1]);
        let r2 = indirect_tsqr(&mut s2, &x2).unwrap();
        // same data (same seed) -> same canonical R (non-negative diag)
        let rd1 = s1.fetch(&r1.r).unwrap();
        let rd2 = s2.fetch(&r2.r).unwrap();
        assert!(rd1.max_abs_diff(&rd2) < 1e-8);
    }
}
