//! SUMMA (Algorithm 4) — the SLATE/ScaLAPACK DGEMM comparator (§8.2).
//!
//! SUMMA distributes X, Y and the output Z over a √p×√p worker grid; at
//! step h every row owner broadcasts X_{i,h} along its grid row and every
//! column owner broadcasts Y_{h,j} down its grid column, then each worker
//! accumulates Z_{i,j} += X_{i,h} Y_{h,j} *in place* — the memory-
//! efficiency advantage the paper credits SLATE with. We generate the
//! static plan (binomial-tree broadcasts, fixed placements, no γ — MPI has
//! no central dispatcher) and time it on the same DES the NumS plans run
//! on, so Fig. 10 compares schedules over an identical network model.

use crate::exec::task::{Plan, Task, Transfer};
use crate::exec::{SimExecutor, SimReport};
use crate::net::model::{ComputeParams, NetParams, SystemMode};
use crate::runtime::kernel::Kernel;
use crate::scheduler::Topology;
use crate::store::ObjectId;

/// SUMMA instance over an n×n DGEMM on a √k×√k *node* grid. Non-square
/// node counts use the next square virtual grid with ranks wrapped onto
/// real nodes round-robin (standard virtual-topology trick).
pub struct Summa {
    /// Physical node count.
    pub nodes: usize,
    /// Global matrix dimension.
    pub n: usize,
}

pub struct SummaOutcome {
    pub report: SimReport,
    /// App. A.5.1 closed-form communication time 2√p·log(√p)·C(n).
    pub analytic_comm_secs: f64,
    pub tasks: usize,
}

impl Summa {
    pub fn new(nodes: usize, n: usize) -> Self {
        assert!(nodes >= 1);
        Self { nodes, n }
    }

    /// Rank -> physical node, cyclic (ScaLAPACK's block-cyclic process
    /// placement): consecutive grid coordinates land on different nodes,
    /// so no single node's NIC funnels a whole broadcast row/column.
    fn node_of_rank(&self, rank: usize, _ranks: usize) -> usize {
        rank % self.nodes
    }

    /// Build the static SUMMA plan and simulate it. SLATE/ScaLAPACK run
    /// one MPI rank per core, so the process grid is worker-granular:
    /// p = nodes × workers_per_node ranks on a ⌊√p⌋ × ⌊√p⌋ virtual grid
    /// (surplus ranks idle, as in practice with non-square counts).
    pub fn run(&self, net: NetParams, compute: ComputeParams, workers_per_node: usize) -> SummaOutcome {
        let ranks = self.nodes * workers_per_node;
        let s = ((ranks as f64).sqrt().floor() as usize).max(1);
        let used = s * s;
        let owner = |i: usize, j: usize| self.node_of_rank(i * s + j, used);
        let bn = self.n / s; // block dimension
        let bytes = (bn * bn * 8) as u64;
        let elems = (bn * bn) as u64;

        // object ids: X = 0..s², Y = s²..2s², Z accumulators = 2s²..3s²
        let x_id = |i: usize, h: usize| (i * s + h) as ObjectId;
        let y_id = |h: usize, j: usize| (s * s + h * s + j) as ObjectId;
        let z_id = |i: usize, j: usize| (2 * s * s + i * s + j) as ObjectId;

        let mut initial: Vec<(ObjectId, usize, u64)> = Vec::new();
        for i in 0..s {
            for j in 0..s {
                initial.push((x_id(i, j), owner(i, j), bytes));
                initial.push((y_id(i, j), owner(i, j), bytes));
            }
        }

        let mut plan = Plan::new();
        for h in 0..s {
            // Broadcast X_{i,h} along row i and Y_{h,j} down column j with a
            // binomial tree: receivers that already hold the block re-send.
            // The DES resolves each Transfer's timing from the src's ready
            // time, so ordering receivers by tree level models log-depth.
            for i in 0..s {
                for j in 0..s {
                    let mut transfers = Vec::new();
                    if j != h {
                        transfers.push(Transfer {
                            obj: x_id(i, h),
                            src: owner(i, broadcast_parent(j, h, s)),
                            elems,
                        });
                    }
                    if i != h {
                        transfers.push(Transfer {
                            obj: y_id(h, j),
                            src: owner(broadcast_parent(i, h, s), j),
                            elems,
                        });
                    }
                    plan.tasks.push(Task {
                        kernel: Kernel::Matmul,
                        inputs: vec![x_id(i, h), y_id(h, j)],
                        in_shapes: vec![vec![bn, bn], vec![bn, bn]],
                        // in-place accumulation: same Z object every step —
                        // the DES charges its memory only once.
                        outputs: vec![(z_id(i, j), vec![bn, bn])],
                        target: owner(i, j),
                        transfers,
                    });
                }
            }
        }

        let topo = Topology::new(self.nodes, workers_per_node, SystemMode::Ray);
        let exec = SimExecutor::new(topo, net, compute);
        let report = exec.run(&plan, &initial);

        let p = (self.nodes * workers_per_node) as f64;
        let analytic =
            2.0 * p.sqrt() * (p.sqrt().log2().max(1.0)) * net.inter.time((bn * bn * 8) as u64 / workers_per_node as u64);
        SummaOutcome {
            tasks: plan.len(),
            report,
            analytic_comm_secs: analytic,
        }
    }
}

/// Parent of `rank` in a binomial broadcast rooted at `root` over `s`
/// ranks: the previous rank in a dissemination order (simple linear-tree
/// approximation whose depth the DES turns into pipeline-parallel sends;
/// with per-NIC serialization this reproduces the log-ish growth of a
/// tree broadcast without modeling MPI internals).
fn broadcast_parent(rank: usize, root: usize, s: usize) -> usize {
    debug_assert!(rank != root);
    // relative position in the ring starting at root
    let rel = (rank + s - root) % s;
    if rel == 1 {
        root
    } else {
        // halve toward the root: parent is root + rel/2
        (root + rel / 2) % s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summa_plan_size() {
        let s = Summa::new(4, 1024);
        let out = s.run(
            NetParams::mpi_testbed(),
            ComputeParams::mpi_testbed(),
            1,
        );
        // 4 ranks -> 2x2 grid: 2 steps × 4 ranks = 8 tasks
        assert_eq!(out.tasks, 8);
        assert!(out.report.makespan > 0.0);
    }

    #[test]
    fn broadcast_parent_reaches_root() {
        let s = 8;
        for root in 0..s {
            for rank in 0..s {
                if rank == root {
                    continue;
                }
                // walking parents must terminate at root
                let mut cur = rank;
                let mut hops = 0;
                while cur != root {
                    cur = broadcast_parent(cur, root, s);
                    hops += 1;
                    assert!(hops <= s, "cycle detected");
                }
                assert!(hops as f64 <= (s as f64).log2() + 1.0 + 1e-9, "not log-depth: {hops}");
            }
        }
    }

    #[test]
    fn memory_stays_flat_under_accumulation() {
        // Z is accumulated in place: SUMMA's peak memory ≈ 3 blocks/worker
        // + broadcast copies, far below materializing s partials.
        let s = Summa::new(4, 512);
        let out = s.run(NetParams::mpi_testbed(), ComputeParams::mpi_testbed(), 1);
        let bn = 512 / 2;
        let block_bytes = (bn * bn * 8) as u64;
        for &m in &out.report.mem_bytes {
            assert!(
                m <= 6 * block_bytes,
                "node holds {m} bytes > 6 blocks ({})",
                6 * block_bytes
            );
        }
    }

    #[test]
    fn summa_scales_with_nodes() {
        let small = Summa::new(4, 2048).run(NetParams::mpi_testbed(), ComputeParams::mpi_testbed(), 4);
        let large = Summa::new(16, 2048).run(NetParams::mpi_testbed(), ComputeParams::mpi_testbed(), 4);
        assert!(
            large.report.makespan < small.report.makespan,
            "16 nodes should beat 4: {} vs {}",
            large.report.makespan,
            small.report.makespan
        );
    }
}
