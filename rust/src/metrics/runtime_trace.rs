//! Low-overhead tracing for the *real* executor: per-task spans, runtime
//! events, and post-run folds into the Fig. 15 machinery.
//!
//! The DES simulator always produced load-over-time curves
//! ([`crate::exec::TraceEvent`] → [`super::trace`]), but the real
//! executor only reported end-of-run aggregates — none of the Eq. 2
//! claims, the prefetch/steal interactions, or the feedback loop's
//! corrections could be *observed* as they happen. This module records
//! them:
//!
//! * **Spans** — one [`TaskSpan`] per executed task: queue-wait (ready →
//!   picked), input-fetch (with demand bytes and prefetch-hit counts),
//!   and kernel execution (kernel kind, tier, thread budget), stamped
//!   against one run-level `Instant` epoch. Workers record spans into
//!   stack-local ring buffers ([`SpanRing`]) — **no locks, no
//!   allocation on the task hot path** — drained once at worker exit.
//! * **Events** — [`RtEvent`]s for cross-node fetches (tagged
//!   prefetch vs demand, with exact bytes), spills, read-backs, replica
//!   evictions, GC frees, steals, and plan-cache hits. Event sites are
//!   already heavyweight (disk I/O, cross-node memcpy, GC), so they go
//!   through one mutex on the recorder — never on the per-input fast
//!   path where nothing moved.
//!
//! Post-run, [`RunRecorder::finish`] folds both into a [`RunTrace`]:
//!
//! * `series_events` — cumulative per-node `(mem, net_in, net_out)`
//!   samples in the *simulator's* [`crate::exec::TraceEvent`] type, so
//!   `summarize_trace`/`trace_to_tsv` work unchanged on real runs. Net
//!   counters are exact (they are built from the same fetch events the
//!   store counters see); the memory curve is a resident-byte *estimate*
//!   relative to run start (creation-time residency is not replayed, and
//!   a GC free of a disk-only copy subtracts like a resident one).
//! * a Chrome trace-event / Perfetto JSON exporter
//!   ([`chrome_trace_json`]) — open the file in `chrome://tracing` or
//!   <https://ui.perfetto.dev>; pid = node, tid = worker.
//! * a [`DivergenceReport`] joining each task's *planned* placement and
//!   transfer bytes (from the [`Plan`]'s committed decisions, the same
//!   Eq. 2 deltas the scheduler charged) against *observed* placement,
//!   bytes, and durations — the feedback loop (PR 5) and plan-cache
//!   replay (PR 7) made inspectable instead of only assertable.
//!
//! Tracing is off by default (`SessionConfig::tracing` / `NUMS_TRACE`):
//! with it off the executor holds no recorder, takes no timestamps, and
//! the run is bit-identical to an untraced one.

use std::sync::Mutex;
use std::time::Instant;

use crate::exec::feedback::RuntimeFeedback;
use crate::exec::sim_exec::TraceEvent;
use crate::exec::task::Plan;
use crate::runtime::KernelTier;
use crate::scheduler::Topology;
use crate::store::ObjectId;

/// Who moved a cross-node byte: the background transfer thread or the
/// worker hot path. Mirrors the `prefetch_bytes` / `demand_pull_bytes`
/// split in [`crate::exec::PrefetchStats`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchOrigin {
    /// Moved by a per-node transfer thread before any worker asked.
    Prefetch,
    /// Moved synchronously while a worker collected task inputs.
    Demand,
}

/// What happened at an [`RtEvent`]'s timestamp.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// `bytes` moved cross-node onto `node` (from `src`).
    Fetch(FetchOrigin),
    /// `bytes` written to `node`'s spill file (sync or async finalize).
    Spill,
    /// `bytes` shed by reusing a current spill file (no write happened).
    SpillReuse,
    /// `bytes` restored from spill into `node`'s store.
    Readback,
    /// `bytes` reclaimed by evicting a replica copy (primary elsewhere).
    ReplicaEvict,
    /// `bytes` reclaimed by lifetime GC (dead intermediate).
    GcFree,
    /// A worker on `node` stole work from `src`; `bytes` holds the
    /// number of migrated tasks, not bytes (the stolen inputs' traffic
    /// shows up as ordinary `Fetch` events when they actually move).
    Steal,
    /// The session served this run's plan from the plan cache (t = 0).
    PlanCacheHit,
    /// A deterministic injected failure fired on `node` (`obj`/`bytes`
    /// describe the victim operation where known). Memory-neutral: the
    /// failed operation moved or freed nothing.
    Fault,
    /// A worker retried after a transient (injected or real) failure,
    /// after a bounded backoff sleep. Memory-neutral.
    Retry,
    /// A lineage-recovery recompute of `obj` completed on `node`;
    /// `bytes` holds the recomputed output bytes. The recompute's memory
    /// effect shows up through its ordinary task span and store events.
    Recompute,
    /// Node `node` was lost (fault injection); `bytes` holds the total
    /// bytes wiped from its store and spill files.
    NodeLoss,
}

/// One timestamped runtime event (everything that is not a task span).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RtEvent {
    /// Seconds since the run epoch.
    pub t: f64,
    /// Node the event happened on (destination, for fetches).
    pub node: usize,
    /// Source node, when the event has one (fetches, steals).
    pub src: Option<usize>,
    /// Object involved, when the event has one.
    pub obj: Option<ObjectId>,
    /// Bytes moved/freed/written ([`EventKind::Steal`]: migrated tasks).
    pub bytes: u64,
    pub kind: EventKind,
}

/// One executed task's span: `ready_t ≤ start_t ≤ fetch_end_t ≤ end_t`,
/// all in seconds since the run epoch. Recorded without allocation on
/// the hot path — `kernel` stays empty until [`RunRecorder::finish`]
/// resolves it from the plan.
#[derive(Clone, Debug)]
pub struct TaskSpan {
    /// Plan task index.
    pub task: usize,
    /// Node whose worker executed the task (≠ planned node when stolen).
    pub node: usize,
    /// Global worker id (`node * threads_per_node + thread`).
    pub worker: usize,
    /// Whether the task ran on a node other than its plan target's.
    pub stolen: bool,
    /// Intra-kernel thread budget the worker's [`crate::runtime::ExecContext`] granted.
    pub threads: usize,
    /// Microkernel tier the kernel dispatched under.
    pub tier: KernelTier,
    /// Inputs found resident thanks to a completed prefetch.
    pub prefetch_hits: u32,
    /// When the task's last dependency was satisfied (enqueue time).
    pub ready_t: f64,
    /// When a worker picked the task.
    pub start_t: f64,
    /// When input collection finished.
    pub fetch_end_t: f64,
    /// When outputs were inserted (kernel + output store time included).
    pub end_t: f64,
    /// Demand bytes the worker moved to collect inputs (0 on full hits).
    pub fetch_bytes: u64,
    /// Kernel label (`Display` of [`crate::runtime::kernel::Kernel`]),
    /// resolved post-run; empty while the span sits in a worker ring.
    pub kernel: String,
}

impl TaskSpan {
    /// Ready-to-picked wait (time spent in a ready deque).
    pub fn queue_wait_secs(&self) -> f64 {
        (self.start_t - self.ready_t).max(0.0)
    }

    /// Input-collection time (demand pulls, spill read-backs).
    pub fn fetch_secs(&self) -> f64 {
        (self.fetch_end_t - self.start_t).max(0.0)
    }

    /// Kernel execution + output insertion time.
    pub fn exec_secs(&self) -> f64 {
        (self.end_t - self.fetch_end_t).max(0.0)
    }
}

/// Hard cap on one worker's span ring (a plan larger than this keeps the
/// newest spans and counts the overwritten ones in `dropped`).
pub const SPAN_RING_CAP: usize = 1 << 16;

/// Fixed-capacity overwrite-oldest ring. Allocated once at worker start,
/// pushed with no locks and no further allocation (`TaskSpan`'s only
/// heap field, `kernel`, is pushed empty).
pub struct SpanRing<T> {
    buf: Vec<T>,
    cap: usize,
    head: usize,
    dropped: u64,
}

impl<T> SpanRing<T> {
    pub fn new(cap: usize) -> Self {
        let cap = cap.clamp(1, SPAN_RING_CAP);
        Self {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
        }
    }

    pub fn push(&mut self, v: T) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.head] = v;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// `(entries, dropped)` — entry order is unspecified once the ring
    /// has wrapped (the post-run fold sorts by timestamp anyway).
    pub fn into_parts(self) -> (Vec<T>, u64) {
        (self.buf, self.dropped)
    }
}

#[derive(Default)]
struct Sink {
    spans: Vec<TaskSpan>,
    events: Vec<RtEvent>,
    dropped_spans: u64,
}

/// Run-scoped recorder: one `Instant` epoch every timestamp derives
/// from, plus a mutexed sink that worker rings drain into at exit and
/// rare events (fetches, spills, steals) push into directly.
pub struct RunRecorder {
    epoch: Instant,
    nodes: usize,
    sink: Mutex<Sink>,
}

impl RunRecorder {
    pub fn new(nodes: usize) -> Self {
        Self {
            epoch: Instant::now(),
            nodes,
            sink: Mutex::new(Sink::default()),
        }
    }

    /// Seconds since the run epoch (monotonic).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// The run epoch itself, for call sites that stamp timestamps while
    /// already holding another lock (e.g. the executor's enqueue path).
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Record one runtime event, stamped now. Takes the sink mutex —
    /// callers are event sites that already did real work (cross-node
    /// transfer, disk I/O, GC), never the per-input nothing-moved path.
    pub fn event(
        &self,
        node: usize,
        src: Option<usize>,
        obj: Option<ObjectId>,
        bytes: u64,
        kind: EventKind,
    ) {
        let t = self.now();
        self.sink.lock().unwrap().events.push(RtEvent {
            t,
            node,
            src,
            obj,
            bytes,
            kind,
        });
    }

    /// Fold a worker's span ring into the sink (worker exit, once).
    pub fn drain_spans(&self, ring: SpanRing<TaskSpan>) {
        let (spans, dropped) = ring.into_parts();
        let mut s = self.sink.lock().unwrap();
        s.spans.extend(spans);
        s.dropped_spans += dropped;
    }

    /// Consume everything recorded so far into a [`RunTrace`]: kernel
    /// labels resolved from the plan, spans/events time-sorted, the
    /// Fig. 15 series fold, and the plan-vs-actual divergence report.
    pub fn finish(&self, plan: &Plan, topo: &Topology) -> RunTrace {
        let (mut spans, mut events, dropped_spans) = {
            let mut s = self.sink.lock().unwrap();
            (
                std::mem::take(&mut s.spans),
                std::mem::take(&mut s.events),
                s.dropped_spans,
            )
        };
        for sp in &mut spans {
            if let Some(t) = plan.tasks.get(sp.task) {
                sp.kernel = format!("{}", t.kernel);
            }
        }
        spans.sort_by(|a, b| {
            a.start_t
                .total_cmp(&b.start_t)
                .then(a.task.cmp(&b.task))
        });
        events.sort_by(|a, b| a.t.total_cmp(&b.t));
        let series_events = fold_series(&spans, &events, plan, self.nodes);
        let divergence = DivergenceReport::build(plan, topo, &spans, &events, self.nodes);
        RunTrace {
            spans,
            events,
            dropped_spans,
            series_events,
            divergence,
        }
    }
}

/// Everything one traced real run produced, attached to
/// [`crate::exec::RealReport::trace`].
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    /// One span per executed task, sorted by start time.
    pub spans: Vec<TaskSpan>,
    /// Runtime events, sorted by time.
    pub events: Vec<RtEvent>,
    /// Spans lost to ring overwrite (0 unless a plan exceeded
    /// [`SPAN_RING_CAP`] tasks on one worker).
    pub dropped_spans: u64,
    /// The spans/events folded into cumulative per-node samples in the
    /// simulator's event type — feed to
    /// [`crate::metrics::summarize_trace`] / [`crate::metrics::trace_to_tsv`].
    pub series_events: Vec<TraceEvent>,
    /// Plan-vs-actual reconciliation (placements, bytes, durations).
    pub divergence: DivergenceReport,
}

impl RunTrace {
    /// Total demand bytes across spans (equals the per-node sum of
    /// `PrefetchStats::demand_pull_bytes` — asserted in the trace suite).
    pub fn span_fetch_bytes(&self) -> u64 {
        self.spans.iter().map(|s| s.fetch_bytes).sum()
    }
}

/// One time-ordered per-node delta during the fold.
struct Delta {
    t: f64,
    node: usize,
    mem: i64,
    net_in: u64,
    net_out: u64,
}

/// Fold spans + events into cumulative per-node samples. Net counters
/// replay the fetch events exactly; the memory curve adds task output
/// bytes at span end, fetched bytes at fetch time, and subtracts
/// spill/evict/GC sheds — a resident-byte estimate relative to run
/// start, clamped at zero.
fn fold_series(
    spans: &[TaskSpan],
    events: &[RtEvent],
    plan: &Plan,
    nodes: usize,
) -> Vec<TraceEvent> {
    let mut deltas: Vec<Delta> = Vec::with_capacity(spans.len() + 2 * events.len());
    for sp in spans {
        let out_bytes = plan
            .tasks
            .get(sp.task)
            .map_or(0, |t| t.out_elems() * 8);
        deltas.push(Delta {
            t: sp.end_t,
            node: sp.node,
            mem: out_bytes as i64,
            net_in: 0,
            net_out: 0,
        });
    }
    for e in events {
        match e.kind {
            EventKind::Fetch(_) => {
                deltas.push(Delta {
                    t: e.t,
                    node: e.node,
                    mem: e.bytes as i64,
                    net_in: e.bytes,
                    net_out: 0,
                });
                if let Some(src) = e.src {
                    if src != e.node && src < nodes {
                        deltas.push(Delta {
                            t: e.t,
                            node: src,
                            mem: 0,
                            net_in: 0,
                            net_out: e.bytes,
                        });
                    }
                }
            }
            EventKind::Spill
            | EventKind::SpillReuse
            | EventKind::ReplicaEvict
            | EventKind::GcFree => deltas.push(Delta {
                t: e.t,
                node: e.node,
                mem: -(e.bytes as i64),
                net_in: 0,
                net_out: 0,
            }),
            EventKind::Readback => deltas.push(Delta {
                t: e.t,
                node: e.node,
                mem: e.bytes as i64,
                net_in: 0,
                net_out: 0,
            }),
            // node loss wipes resident bytes like a GC free; the fault/
            // retry/recompute instants are memory-neutral (a recompute's
            // output lands through its ordinary task span)
            EventKind::NodeLoss => deltas.push(Delta {
                t: e.t,
                node: e.node,
                mem: -(e.bytes as i64),
                net_in: 0,
                net_out: 0,
            }),
            EventKind::Steal
            | EventKind::PlanCacheHit
            | EventKind::Fault
            | EventKind::Retry
            | EventKind::Recompute => {}
        }
    }
    deltas.sort_by(|a, b| a.t.total_cmp(&b.t));
    let mut mem = vec![0i128; nodes];
    let mut net_in = vec![0u64; nodes];
    let mut net_out = vec![0u64; nodes];
    let mut out = Vec::with_capacity(deltas.len());
    for d in deltas {
        if d.node >= nodes {
            continue;
        }
        mem[d.node] = (mem[d.node] + d.mem as i128).max(0);
        net_in[d.node] += d.net_in;
        net_out[d.node] += d.net_out;
        out.push(TraceEvent {
            t: d.t,
            node: d.node,
            mem_bytes: mem[d.node] as u64,
            net_in_bytes: net_in[d.node],
            net_out_bytes: net_out[d.node],
        });
    }
    out
}

/// One task's planned-vs-observed row.
#[derive(Clone, Debug, Default)]
pub struct TaskDivergence {
    pub task: usize,
    /// Node the scheduler placed the task on.
    pub planned_node: usize,
    /// Node that actually executed it.
    pub observed_node: usize,
    pub stolen: bool,
    /// Cross-node input bytes the plan committed for this task (Eq. 2's
    /// charged NIC deltas toward the planned node).
    pub planned_in_bytes: u64,
    /// Demand bytes the executing worker actually moved.
    pub observed_fetch_bytes: u64,
    pub queue_wait_secs: f64,
    pub fetch_secs: f64,
    pub exec_secs: f64,
}

/// One node's planned-vs-observed totals. `observed_in_bytes ==
/// prefetch_in_bytes + demand_in_bytes == ` the run's `net_in` store
/// delta — the accounting identity the trace suite asserts.
#[derive(Clone, Debug, Default)]
pub struct NodeDivergence {
    pub node: usize,
    /// Tasks the plan targeted at this node.
    pub planned_tasks: usize,
    /// Tasks this node's workers actually ran.
    pub observed_tasks: usize,
    /// Inbound bytes the plan's committed transfers predicted.
    pub planned_in_bytes: u64,
    /// Outbound bytes the plan's committed transfers predicted.
    pub planned_out_bytes: u64,
    /// Inbound bytes observed (all fetch events landing here).
    pub observed_in_bytes: u64,
    /// Outbound bytes observed (all fetch events sourced here).
    pub observed_out_bytes: u64,
    /// Observed inbound bytes moved by the transfer threads.
    pub prefetch_in_bytes: u64,
    /// Observed inbound bytes moved on the worker hot path.
    pub demand_in_bytes: u64,
    pub spilled_bytes: u64,
    pub readback_bytes: u64,
}

/// Plan-vs-actual reconciliation for one run.
#[derive(Clone, Debug, Default)]
pub struct DivergenceReport {
    /// Per executed task, span order.
    pub tasks: Vec<TaskDivergence>,
    /// Per node.
    pub nodes: Vec<NodeDivergence>,
}

impl DivergenceReport {
    fn build(
        plan: &Plan,
        topo: &Topology,
        spans: &[TaskSpan],
        events: &[RtEvent],
        nodes: usize,
    ) -> Self {
        let planned_nic = RuntimeFeedback::planned_nic_bytes(plan, topo);
        let mut per_node: Vec<NodeDivergence> = (0..nodes)
            .map(|n| NodeDivergence {
                node: n,
                planned_in_bytes: planned_nic.get(n).map_or(0, |p| p.0),
                planned_out_bytes: planned_nic.get(n).map_or(0, |p| p.1),
                ..Default::default()
            })
            .collect();
        for t in &plan.tasks {
            let n = topo.node_of(t.target);
            if n < nodes {
                per_node[n].planned_tasks += 1;
            }
        }
        let tasks = spans
            .iter()
            .map(|sp| {
                if sp.node < nodes {
                    per_node[sp.node].observed_tasks += 1;
                }
                let (planned_node, planned_in) = plan
                    .tasks
                    .get(sp.task)
                    .map(|t| {
                        let dst = topo.node_of(t.target);
                        let bytes = t
                            .transfers
                            .iter()
                            .filter(|tr| topo.node_of(tr.src) != dst)
                            .map(|tr| tr.bytes())
                            .sum();
                        (dst, bytes)
                    })
                    .unwrap_or((sp.node, 0));
                TaskDivergence {
                    task: sp.task,
                    planned_node,
                    observed_node: sp.node,
                    stolen: sp.stolen,
                    planned_in_bytes: planned_in,
                    observed_fetch_bytes: sp.fetch_bytes,
                    queue_wait_secs: sp.queue_wait_secs(),
                    fetch_secs: sp.fetch_secs(),
                    exec_secs: sp.exec_secs(),
                }
            })
            .collect();
        for e in events {
            match e.kind {
                EventKind::Fetch(origin) => {
                    if e.node < nodes {
                        let nd = &mut per_node[e.node];
                        nd.observed_in_bytes += e.bytes;
                        match origin {
                            FetchOrigin::Prefetch => nd.prefetch_in_bytes += e.bytes,
                            FetchOrigin::Demand => nd.demand_in_bytes += e.bytes,
                        }
                    }
                    if let Some(src) = e.src {
                        if src != e.node && src < nodes {
                            per_node[src].observed_out_bytes += e.bytes;
                        }
                    }
                }
                EventKind::Spill => {
                    if e.node < nodes {
                        per_node[e.node].spilled_bytes += e.bytes;
                    }
                }
                EventKind::Readback => {
                    if e.node < nodes {
                        per_node[e.node].readback_bytes += e.bytes;
                    }
                }
                _ => {}
            }
        }
        Self {
            tasks,
            nodes: per_node,
        }
    }

    /// Tasks that ran on a node other than their planned target.
    pub fn migrated_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.stolen).count()
    }

    /// Human-readable reconciliation: one line per node plus a header.
    pub fn summary(&self) -> String {
        let total = self.tasks.len();
        let migrated = self.migrated_tasks();
        let mut out = format!(
            "plan-vs-actual: {}/{} tasks on planned node ({migrated} migrated)\n",
            total - migrated,
            total
        );
        for n in &self.nodes {
            out.push_str(&format!(
                "  node {}: tasks {}->{} | in {} planned -> {} observed \
                 ({} prefetch + {} demand) | out {} -> {} | spill {} readback {}\n",
                n.node,
                n.planned_tasks,
                n.observed_tasks,
                n.planned_in_bytes,
                n.observed_in_bytes,
                n.prefetch_in_bytes,
                n.demand_in_bytes,
                n.planned_out_bytes,
                n.observed_out_bytes,
                n.spilled_bytes,
                n.readback_bytes
            ));
        }
        out
    }
}

/// Minimal JSON string escape (quotes, backslashes, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn us(secs: f64) -> String {
    format!("{:.3}", secs * 1e6)
}

fn instant_name(kind: EventKind) -> &'static str {
    match kind {
        EventKind::Fetch(FetchOrigin::Prefetch) => "fetch.prefetch",
        EventKind::Fetch(FetchOrigin::Demand) => "fetch.demand",
        EventKind::Spill => "spill",
        EventKind::SpillReuse => "spill.reuse",
        EventKind::Readback => "readback",
        EventKind::ReplicaEvict => "replica.evict",
        EventKind::GcFree => "gc.free",
        EventKind::Steal => "steal",
        EventKind::PlanCacheHit => "plan.cache.hit",
        EventKind::Fault => "fault.inject",
        EventKind::Retry => "retry",
        EventKind::Recompute => "recompute",
        EventKind::NodeLoss => "node.loss",
    }
}

/// Serialize a [`RunTrace`] to Chrome trace-event JSON (the format
/// `chrome://tracing` and Perfetto load): spans become complete (`"X"`)
/// events named by kernel, runtime events become instants (`"i"`);
/// pid = node, tid = worker (0 for non-worker events), timestamps in
/// microseconds since the run epoch. Hand-rolled — the offline image
/// vendors no serde ([`crate::util::json`] parses it back in tests).
pub fn chrome_trace_json(trace: &RunTrace) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for sp in &trace.spans {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"task\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":{},\"tid\":{},\"args\":{{\"task\":{},\"tier\":\"{:?}\",\
             \"threads\":{},\"queue_wait_us\":{},\"fetch_us\":{},\
             \"fetch_bytes\":{},\"prefetch_hits\":{},\"stolen\":{}}}}}",
            esc(&sp.kernel),
            us(sp.start_t),
            us((sp.end_t - sp.start_t).max(0.0)),
            sp.node,
            sp.worker,
            sp.task,
            sp.tier,
            sp.threads,
            us(sp.queue_wait_secs()),
            us(sp.fetch_secs()),
            sp.fetch_bytes,
            sp.prefetch_hits,
            sp.stolen
        ));
    }
    for e in &trace.events {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"runtime\",\"ph\":\"i\",\"ts\":{},\
             \"pid\":{},\"tid\":0,\"s\":\"p\",\"args\":{{\"bytes\":{}",
            instant_name(e.kind),
            us(e.t),
            e.node,
            e.bytes
        ));
        if let Some(src) = e.src {
            out.push_str(&format!(",\"src\":{src}"));
        }
        if let Some(obj) = e.obj {
            out.push_str(&format!(",\"obj\":{obj}"));
        }
        out.push_str("}}");
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::model::SystemMode;
    use crate::runtime::kernel::Kernel;
    use crate::exec::task::{Task, Transfer};

    fn tiny_plan() -> Plan {
        Plan {
            tasks: vec![
                Task {
                    kernel: Kernel::Scale(2.0),
                    inputs: vec![1],
                    in_shapes: vec![vec![2, 2]],
                    outputs: vec![(10, vec![2, 2])],
                    target: 0,
                    transfers: vec![],
                },
                Task {
                    kernel: Kernel::Neg,
                    inputs: vec![10],
                    in_shapes: vec![vec![2, 2]],
                    outputs: vec![(11, vec![2, 2])],
                    target: 1,
                    transfers: vec![Transfer {
                        obj: 10,
                        src: 0,
                        elems: 4,
                    }],
                },
            ],
        }
    }

    fn span(task: usize, node: usize, start: f64, end: f64, bytes: u64) -> TaskSpan {
        TaskSpan {
            task,
            node,
            worker: node,
            stolen: false,
            threads: 1,
            tier: KernelTier::Scalar,
            prefetch_hits: 0,
            ready_t: start,
            start_t: start,
            fetch_end_t: start,
            end_t: end,
            fetch_bytes: bytes,
            kernel: String::new(),
        }
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut r = SpanRing::new(2);
        r.push(1u32);
        r.push(2);
        assert_eq!(r.dropped(), 0);
        r.push(3);
        r.push(4);
        let (buf, dropped) = r.into_parts();
        assert_eq!(dropped, 2);
        assert_eq!(buf.len(), 2);
        assert!(buf.contains(&3) && buf.contains(&4));
    }

    #[test]
    fn recorder_timestamps_are_monotone_and_finish_labels_kernels() {
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let plan = tiny_plan();
        let rec = RunRecorder::new(2);
        let t0 = rec.now();
        let mut ring = SpanRing::new(8);
        ring.push(span(1, 1, 0.002, 0.003, 32));
        ring.push(span(0, 0, 0.001, 0.002, 0));
        rec.event(1, Some(0), Some(10), 32, EventKind::Fetch(FetchOrigin::Demand));
        rec.drain_spans(ring);
        let t1 = rec.now();
        assert!(t1 >= t0 && t0 >= 0.0);
        let tr = rec.finish(&plan, &topo);
        assert_eq!(tr.spans.len(), 2);
        // sorted by start time, labels resolved
        assert_eq!(tr.spans[0].task, 0);
        assert_eq!(tr.spans[0].kernel, format!("{}", plan.tasks[0].kernel));
        assert!(!tr.spans[1].kernel.is_empty());
        assert_eq!(tr.dropped_spans, 0);
        assert_eq!(tr.span_fetch_bytes(), 32);
    }

    #[test]
    fn series_fold_replays_net_exactly_and_estimates_mem() {
        let plan = tiny_plan();
        let spans = vec![span(0, 0, 0.001, 0.002, 0), span(1, 1, 0.003, 0.004, 32)];
        let events = vec![RtEvent {
            t: 0.0025,
            node: 1,
            src: Some(0),
            obj: Some(10),
            bytes: 32,
            kind: EventKind::Fetch(FetchOrigin::Demand),
        }];
        let series = fold_series(&spans, &events, &plan, 2);
        let per = crate::metrics::trace::per_node_series(&series, 2);
        // node 1 received exactly the fetched bytes
        assert_eq!(per[1].final_net_in(), 32);
        assert_eq!(per[0].final_net_in(), 0);
        assert_eq!(per[0].net_out_bytes.last().copied().unwrap(), 32);
        // node 0: task 0's output (4 elems) resident
        assert_eq!(per[0].peak_mem(), 32);
        // node 1: fetched input + its own output
        assert_eq!(per[1].peak_mem(), 64);
        // timestamps are sorted within each node
        for s in &per {
            assert!(s.t.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn mem_estimate_clamps_at_zero_on_overshed() {
        let plan = Plan::default();
        let events = vec![RtEvent {
            t: 0.001,
            node: 0,
            src: None,
            obj: Some(5),
            bytes: 640,
            kind: EventKind::GcFree,
        }];
        let series = fold_series(&[], &events, &plan, 1);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].mem_bytes, 0, "sheds below run-start clamp at 0");
    }

    #[test]
    fn divergence_joins_plan_against_observation() {
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let plan = tiny_plan();
        // task 1 was planned on node 1 but stolen by node 0
        let mut sp1 = span(1, 0, 0.003, 0.004, 32);
        sp1.stolen = true;
        let spans = vec![span(0, 0, 0.001, 0.002, 0), sp1];
        let events = vec![
            RtEvent {
                t: 0.0025,
                node: 0,
                src: None,
                obj: None,
                bytes: 1,
                kind: EventKind::Steal,
            },
            RtEvent {
                t: 0.0026,
                node: 0,
                src: Some(1),
                obj: Some(10),
                bytes: 32,
                kind: EventKind::Fetch(FetchOrigin::Demand),
            },
        ];
        let d = DivergenceReport::build(&plan, &topo, &spans, &events, 2);
        assert_eq!(d.tasks.len(), 2);
        assert_eq!(d.migrated_tasks(), 1);
        let t1 = d.tasks.iter().find(|t| t.task == 1).unwrap();
        assert_eq!(t1.planned_node, 1);
        assert_eq!(t1.observed_node, 0);
        assert_eq!(t1.planned_in_bytes, 32, "committed transfer of 4 elems");
        assert_eq!(t1.observed_fetch_bytes, 32);
        assert_eq!(d.nodes[1].planned_tasks, 1);
        assert_eq!(d.nodes[1].observed_tasks, 0);
        assert_eq!(d.nodes[0].observed_in_bytes, 32);
        assert_eq!(d.nodes[0].demand_in_bytes, 32);
        assert_eq!(d.nodes[0].prefetch_in_bytes, 0);
        // the plan predicted node 1 would receive; observation disagrees
        assert_eq!(d.nodes[1].planned_in_bytes, 32);
        assert_eq!(d.nodes[1].observed_in_bytes, 0);
        let s = d.summary();
        assert!(s.contains("1 migrated"), "{s}");
        assert!(s.contains("node 0"), "{s}");
    }

    #[test]
    fn chrome_json_shape_and_escaping() {
        let mut sp = span(0, 0, 0.001, 0.002, 8);
        sp.kernel = "Ew(\"Add\")\\x".into();
        let trace = RunTrace {
            spans: vec![sp],
            events: vec![RtEvent {
                t: 0.0015,
                node: 0,
                src: Some(1),
                obj: Some(7),
                bytes: 64,
                kind: EventKind::Spill,
            }],
            ..Default::default()
        };
        let js = chrome_trace_json(&trace);
        assert!(js.starts_with("{\"traceEvents\":["));
        assert!(js.ends_with("]}"));
        assert!(js.contains("\\\"Add\\\""), "quotes escaped: {js}");
        assert!(js.contains("\\\\x"), "backslash escaped: {js}");
        assert!(js.contains("\"ph\":\"X\""));
        assert!(js.contains("\"ph\":\"i\""));
        assert!(js.contains("\"name\":\"spill\""));
        assert!(js.contains("\"src\":1"));
        // parses with the vendored reader
        let v = crate::util::json::parse(&js).expect("valid JSON");
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(evs.len(), 2);
    }

    #[test]
    fn span_phase_durations_never_negative() {
        let mut sp = span(0, 0, 0.005, 0.004, 0);
        sp.ready_t = 0.006; // degenerate ordering must clamp, not underflow
        sp.fetch_end_t = 0.0055;
        assert!(sp.queue_wait_secs() >= 0.0);
        assert!(sp.fetch_secs() >= 0.0);
        assert!(sp.exec_secs() >= 0.0);
    }
}
