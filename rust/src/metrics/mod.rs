//! Load traces and report writers (the Fig. 15 load-over-time data),
//! plus the real-runtime tracing recorder (spans, events, divergence).

pub mod runtime_trace;
pub mod trace;

pub use runtime_trace::{
    chrome_trace_json, DivergenceReport, EventKind, FetchOrigin, NodeDivergence, RtEvent,
    RunRecorder, RunTrace, SpanRing, TaskDivergence, TaskSpan,
};
pub use trace::{per_node_series, summarize_trace, trace_to_tsv, NodeSeries};
