//! Load traces and report writers (Fig. 15, EXPERIMENTS.md tables).

pub mod trace;

pub use trace::{summarize_trace, trace_to_tsv, NodeSeries};
