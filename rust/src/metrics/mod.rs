//! Load traces and report writers (the Fig. 15 load-over-time data).

pub mod trace;

pub use trace::{summarize_trace, trace_to_tsv, NodeSeries};
