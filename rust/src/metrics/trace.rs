//! Per-node load time-series (Fig. 15): build per-node series from the
//! DES trace events, summarize balance, dump TSV for plotting.

use crate::exec::TraceEvent;

/// One node's sampled (t, mem, net_in, net_out) series.
#[derive(Clone, Debug, Default)]
pub struct NodeSeries {
    pub node: usize,
    pub t: Vec<f64>,
    pub mem_bytes: Vec<u64>,
    pub net_in_bytes: Vec<u64>,
    pub net_out_bytes: Vec<u64>,
}

impl NodeSeries {
    pub fn peak_mem(&self) -> u64 {
        self.mem_bytes.iter().copied().max().unwrap_or(0)
    }

    pub fn final_net_in(&self) -> u64 {
        self.net_in_bytes.last().copied().unwrap_or(0)
    }
}

/// Split raw events into per-node, time-sorted series.
pub fn per_node_series(events: &[TraceEvent], nodes: usize) -> Vec<NodeSeries> {
    let mut out: Vec<NodeSeries> = (0..nodes)
        .map(|n| NodeSeries {
            node: n,
            ..Default::default()
        })
        .collect();
    let mut sorted: Vec<&TraceEvent> = events.iter().collect();
    // total_cmp: a NaN timestamp (e.g. from a degenerate modeled rate)
    // must sort deterministically, not panic the whole report path.
    sorted.sort_by(|a, b| a.t.total_cmp(&b.t));
    for e in sorted {
        let s = &mut out[e.node];
        s.t.push(e.t);
        s.mem_bytes.push(e.mem_bytes);
        s.net_in_bytes.push(e.net_in_bytes);
        s.net_out_bytes.push(e.net_out_bytes);
    }
    out
}

/// Summary of a trace: (max peak mem, mean peak mem, max net_in, mean
/// net_in, balance ratio max/mean of mem). "Densely clustered curves"
/// in the paper = balance ratio near 1.
#[derive(Clone, Copy, Debug)]
pub struct TraceSummary {
    pub max_peak_mem: u64,
    pub mean_peak_mem: f64,
    pub max_net_in: u64,
    pub mean_net_in: f64,
    pub mem_balance_ratio: f64,
}

pub fn summarize_trace(events: &[TraceEvent], nodes: usize) -> TraceSummary {
    let series = per_node_series(events, nodes);
    let peaks: Vec<u64> = series.iter().map(|s| s.peak_mem()).collect();
    let ins: Vec<u64> = series.iter().map(|s| s.final_net_in()).collect();
    let max_peak = peaks.iter().copied().max().unwrap_or(0);
    let mean_peak = peaks.iter().sum::<u64>() as f64 / nodes.max(1) as f64;
    TraceSummary {
        max_peak_mem: max_peak,
        mean_peak_mem: mean_peak,
        max_net_in: ins.iter().copied().max().unwrap_or(0),
        mean_net_in: ins.iter().sum::<u64>() as f64 / nodes.max(1) as f64,
        mem_balance_ratio: max_peak as f64 / mean_peak.max(1.0),
    }
}

/// TSV dump: `t  node  mem_bytes  net_in_bytes  net_out_bytes`.
pub fn trace_to_tsv(events: &[TraceEvent]) -> String {
    let mut out = String::from("t\tnode\tmem_bytes\tnet_in_bytes\tnet_out_bytes\n");
    for e in events {
        out.push_str(&format!(
            "{:.6}\t{}\t{}\t{}\t{}\n",
            e.t, e.node, e.mem_bytes, e.net_in_bytes, e.net_out_bytes
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, node: usize, mem: u64, nin: u64) -> TraceEvent {
        TraceEvent {
            t,
            node,
            mem_bytes: mem,
            net_in_bytes: nin,
            net_out_bytes: 0,
        }
    }

    #[test]
    fn series_split_and_sorted() {
        let events = vec![ev(2.0, 0, 30, 5), ev(1.0, 0, 10, 0), ev(1.5, 1, 20, 0)];
        let s = per_node_series(&events, 2);
        assert_eq!(s[0].t, vec![1.0, 2.0]);
        assert_eq!(s[0].peak_mem(), 30);
        assert_eq!(s[1].peak_mem(), 20);
    }

    #[test]
    fn summary_balance_ratio() {
        let events = vec![ev(1.0, 0, 100, 0), ev(1.0, 1, 100, 0)];
        let sm = summarize_trace(&events, 2);
        assert!((sm.mem_balance_ratio - 1.0).abs() < 1e-9);
        let skew = vec![ev(1.0, 0, 300, 0), ev(1.0, 1, 100, 0)];
        assert!(summarize_trace(&skew, 2).mem_balance_ratio > 1.4);
    }

    #[test]
    fn nan_timestamp_does_not_panic() {
        let events = vec![ev(f64::NAN, 0, 1, 0), ev(1.0, 0, 2, 0), ev(0.5, 1, 3, 0)];
        let s = per_node_series(&events, 2);
        // NaN sorts last under total_cmp; finite entries stay ordered.
        assert_eq!(s[0].t.len(), 2);
        assert_eq!(s[0].t[0], 1.0);
        assert!(s[0].t[1].is_nan());
        assert_eq!(s[1].peak_mem(), 3);
        let sm = summarize_trace(&events, 2);
        assert_eq!(sm.max_peak_mem, 2);
    }

    #[test]
    fn tsv_has_header_and_rows() {
        let t = trace_to_tsv(&[ev(0.5, 1, 8, 8)]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("t\tnode"));
    }
}
