//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! This is the only module that touches the `xla` crate, and that crate
//! cannot be fetched in the offline build image — so the xla-backed
//! implementation lives behind the `pjrt` cargo feature (enable it *and*
//! add the `xla` dependency manually to use it). Default builds get a stub
//! with the same API whose constructor always errors, which makes
//! [`crate::runtime::Backend::auto`] fall back to the native kernels.
//!
//! The interchange format is HLO *text* (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! Executables are compiled lazily per manifest entry and cached. A process
//! has one `PjRtClient::cpu()`; the client and compiled executables are
//! wrapped in a mutex-protected cache and the *execution* call itself is
//! serialized per-executable — the upstream PJRT CPU client is thread-safe
//! for execution, but the `xla` crate's bindings do not declare `Send`, so
//! we keep a conservative single execution lock (measured in §Perf; the
//! real executor overlaps native kernels with PJRT calls).

#[cfg(feature = "pjrt")]
mod xla_impl {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use anyhow::{anyhow, bail, Context, Result};

    use crate::runtime::exec_ctx::ExecContext;
    use crate::runtime::kernel::Kernel;
    use crate::runtime::manifest::{Manifest, ManifestEntry};
    use crate::store::Block;

    struct Inner {
        client: xla::PjRtClient,
        /// artifact file path -> compiled executable
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    // SAFETY: the PJRT CPU client is internally synchronized for compilation
    // and execution (it is the same client the Python jax runtime shares
    // across threads). The `xla` crate merely wraps raw pointers without
    // declaring Send. All access from our side is additionally serialized by
    // the Mutex in `PjrtRuntime`, so no unsynchronized aliasing can occur.
    unsafe impl Send for Inner {}

    /// Lazily-compiling PJRT kernel runtime.
    pub struct PjrtRuntime {
        inner: Mutex<Inner>,
        pub manifest: Manifest,
        /// Executions performed (for perf reports).
        pub exec_count: std::sync::atomic::AtomicU64,
    }

    impl PjrtRuntime {
        /// Create a runtime over the artifacts in `dir` (must contain
        /// `manifest.tsv`).
        pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
            let manifest = Manifest::load(&dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            Ok(Self {
                inner: Mutex::new(Inner {
                    client,
                    executables: HashMap::new(),
                }),
                manifest,
                exec_count: std::sync::atomic::AtomicU64::new(0),
            })
        }

        /// Whether this runtime can execute `kernel` over the given shapes.
        pub fn supports(&self, kernel: &Kernel, input_shapes: &[Vec<usize>]) -> bool {
            kernel
                .manifest_name()
                .and_then(|n| self.manifest.lookup(n, input_shapes))
                .is_some()
        }

        fn entry_for(&self, kernel: &Kernel, input_shapes: &[Vec<usize>]) -> Result<ManifestEntry> {
            let name = kernel
                .manifest_name()
                .ok_or_else(|| anyhow!("{kernel} has no AOT artifact (native-only kernel)"))?;
            self.manifest
                .lookup(name, input_shapes)
                .cloned()
                .ok_or_else(|| anyhow!("no artifact for {name} with inputs {input_shapes:?}"))
        }

        /// Execute `kernel` on real blocks through the compiled artifact.
        /// The PJRT CPU client owns its internal thread pool, so `ctx`'s
        /// budget is advisory here; it is accepted for signature parity
        /// with the native path (the executor threads one context through
        /// every backend).
        pub fn execute(&self, kernel: &Kernel, inputs: &[&Block], _ctx: &ExecContext) -> Result<Vec<Block>> {
            let shapes: Vec<Vec<usize>> = inputs.iter().map(|b| b.shape.clone()).collect();
            let entry = self.entry_for(kernel, &shapes)?;

            let mut inner = self.inner.lock().unwrap();
            // compile-on-first-use, cached thereafter
            let key = entry.file.to_string_lossy().to_string();
            if !inner.executables.contains_key(&key) {
                let proto = xla::HloModuleProto::from_text_file(&entry.file)
                    .map_err(|e| anyhow!("parse {:?}: {e:?}", entry.file))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = inner
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {:?}: {e:?}", entry.file))?;
                inner.executables.insert(key.clone(), exe);
            }
            let exe = &inner.executables[&key];

            // Blocks are row-major f64; literals take the same layout.
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|b| {
                    let lit = xla::Literal::vec1(b.buf());
                    let dims: Vec<i64> = b.shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
                })
                .collect::<Result<_>>()?;

            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {kernel}: {e:?}"))?;
            self.exec_count
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let root = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            // aot.py lowers with return_tuple=True: unwrap the tuple.
            let mut parts = root
                .to_tuple()
                .map_err(|e| anyhow!("to_tuple: {e:?}"))?;
            if parts.len() != entry.n_outputs {
                bail!(
                    "{kernel}: artifact returned {} outputs, manifest says {}",
                    parts.len(),
                    entry.n_outputs
                );
            }
            let mut out = Vec::with_capacity(parts.len());
            for (lit, shape) in parts.drain(..).zip(&entry.output_shapes) {
                let v: Vec<f64> = lit
                    .to_vec()
                    .map_err(|e| anyhow!("literal to_vec: {e:?}"))
                    .context("output literal")?;
                out.push(Block::from_vec(shape, v));
            }
            Ok(out)
        }

        /// Number of distinct compiled executables (for perf reports).
        pub fn compiled_count(&self) -> usize {
            self.inner.lock().unwrap().executables.len()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use xla_impl::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::sync::atomic::AtomicU64;

    use anyhow::{anyhow, Result};

    use crate::runtime::exec_ctx::ExecContext;
    use crate::runtime::kernel::Kernel;
    use crate::runtime::manifest::Manifest;
    use crate::store::Block;

    /// API-compatible stand-in used when the `pjrt` feature is off: the
    /// constructor always errors, so composite backends route everything
    /// to the native kernels.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
        pub exec_count: AtomicU64,
    }

    impl PjrtRuntime {
        pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
            // still requires a manifest, to mirror the real constructor's
            // failure mode on a fresh checkout
            let _ = Manifest::load(&dir)?;
            Err(anyhow!(
                "pjrt support not compiled in (enable the `pjrt` feature and \
                 add the `xla` dependency); using the native backend"
            ))
        }

        pub fn supports(&self, _kernel: &Kernel, _input_shapes: &[Vec<usize>]) -> bool {
            false
        }

        pub fn execute(
            &self,
            kernel: &Kernel,
            _inputs: &[&Block],
            _ctx: &ExecContext,
        ) -> Result<Vec<Block>> {
            Err(anyhow!("no artifact runtime for {kernel}: pjrt feature disabled"))
        }

        pub fn compiled_count(&self) -> usize {
            0
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;
