//! Per-execution kernel configuration — the context object that replaced
//! the old process-global parallelism hint in `linalg::dense`.
//!
//! Every [`crate::runtime::Backend::execute`] call receives an
//! [`ExecContext`] describing how much intra-kernel parallelism the caller
//! grants and where (which simulated node) the task runs. The real
//! executor derives one context per worker thread so that
//! `executor workers × kernel threads` never oversubscribes the host;
//! standalone callers (benches, tests, the serial GLM reference) use
//! [`ExecContext::host_default`], which grants the whole machine.
//!
//! Because the budget is a plain value threaded through call arguments,
//! two `Session`s with different topologies in one process can no longer
//! clobber each other's kernel parallelism — there is no global mutable
//! state left to race on.
//!
//! `NUMS_MATMUL_THREADS` overrides the budget of any context at
//! construction time (`1` forces serial kernels; useful on shared CI
//! runners). Like the budget, the kernel tier ([`KernelTier`]) is part of
//! the context: resolved once at construction (`KernelTier::detect()` /
//! `NUMS_KERNEL_TIER`), never re-detected from kernel hot loops.

use super::tier::KernelTier;

/// Hard cap on intra-kernel threads: beyond this the blocked kernels are
/// memory-bound and extra threads only add spawn/join overhead.
const MAX_KERNEL_THREADS: usize = 8;

/// The host's core count (1 if it cannot be determined) — the single
/// source every pool- and budget-sizing decision derives from.
pub(crate) fn host_threads() -> usize {
    std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1)
}

/// Execution context handed to kernel backends for one task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecContext {
    /// Intra-kernel thread budget (>= 1). Kernels may use fewer threads
    /// (small inputs stay serial) but never more.
    pub kernel_threads: usize,
    /// Simulated node the task executes on (diagnostics / traces).
    pub node: usize,
    /// Whether the owning executor runs with work stealing (so kernels
    /// and traces can report the mode they ran under).
    pub stealing: bool,
    /// Which microkernel implementation contraction/element-wise kernels
    /// dispatch to. Defaults to the process-wide [`KernelTier::detect`]
    /// decision; sessions pin it to `Scalar` under
    /// `SessionConfig::strict_kernels`.
    pub tier: KernelTier,
}

impl ExecContext {
    /// Context with an explicit thread budget. `NUMS_MATMUL_THREADS`
    /// overrides `kernel_threads` when set to a positive integer.
    pub fn new(kernel_threads: usize, node: usize, stealing: bool) -> Self {
        let budget = std::env::var("NUMS_MATMUL_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| kernel_threads.max(1));
        Self {
            kernel_threads: budget,
            node,
            stealing,
            tier: KernelTier::detect(),
        }
    }

    /// Pin this context to an explicit kernel tier (resolved against the
    /// `NUMS_KERNEL_TIER` override and hardware capability — a `Simd`
    /// request on a non-AVX2 host degrades to `Scalar`).
    pub fn with_tier(mut self, tier: KernelTier) -> Self {
        self.tier = KernelTier::resolve(tier);
        self
    }

    /// Whole-host context for standalone kernel calls (benches, tests,
    /// driver-side math): budget = available cores, capped.
    pub fn host_default() -> Self {
        Self::new(host_threads().min(MAX_KERNEL_THREADS), 0, false)
    }

    /// Context for one of `concurrent_workers` executor threads running
    /// kernels at the same time: the host's cores are divided evenly so
    /// nested parallelism does not oversubscribe the machine.
    pub fn shared(concurrent_workers: usize, node: usize, stealing: bool) -> Self {
        let budget = (host_threads() / concurrent_workers.max(1)).clamp(1, MAX_KERNEL_THREADS);
        Self::new(budget, node, stealing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_are_at_least_one() {
        // `new` clamps a zero request (env override, if set, is >= 1 too)
        assert!(ExecContext::new(0, 0, false).kernel_threads >= 1);
        assert!(ExecContext::host_default().kernel_threads >= 1);
        assert!(ExecContext::shared(1 << 20, 3, true).kernel_threads >= 1);
        assert!(host_threads() >= 1);
        // dividing the host among absurdly many workers leaves exactly 1
        // (unless the env override is active, e.g. on CI)
        if std::env::var("NUMS_MATMUL_THREADS").is_err() {
            assert_eq!(ExecContext::shared(1 << 20, 3, true).kernel_threads, 1);
        }
    }

    #[test]
    fn shared_divides_the_host() {
        let hw = host_threads();
        let one = ExecContext::shared(1, 0, false);
        // a single worker gets the whole (capped) machine unless the env
        // override is active in this test environment
        if std::env::var("NUMS_MATMUL_THREADS").is_err() {
            assert_eq!(one.kernel_threads, hw.min(8));
        }
        assert!(ExecContext::shared(4, 0, false).kernel_threads <= one.kernel_threads);
    }

    #[test]
    fn carries_node_and_mode() {
        let c = ExecContext::new(2, 5, true);
        assert_eq!(c.node, 5);
        assert!(c.stealing);
        assert_eq!(c.tier, KernelTier::detect());
    }

    #[test]
    fn with_tier_pins_scalar() {
        // a scalar pin always sticks (strict sessions depend on this)
        let c = ExecContext::host_default().with_tier(KernelTier::Scalar);
        assert_eq!(c.tier, KernelTier::Scalar);
        // a simd request resolves to at most what the host can run
        let s = ExecContext::host_default().with_tier(KernelTier::Simd);
        assert_eq!(s.tier, KernelTier::resolve(KernelTier::Simd));
    }
}
