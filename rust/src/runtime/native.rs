//! Pure-Rust kernel backend.
//!
//! Implements every [`Kernel`] for arbitrary block shapes. Three roles:
//! 1. fallback for (kernel, shape) pairs without an AOT artifact,
//! 2. host for the factorization/tensor kernels PJRT cannot run,
//! 3. independent oracle the PJRT backend is cross-checked against
//!    (`rust/tests/integration_runtime.rs`).

use anyhow::{bail, Result};

use crate::linalg::{dense, microkernel};
use crate::store::block::pool;
use crate::store::Block;

use super::exec_ctx::ExecContext;
use super::kernel::{BinOp, EwStep, Kernel};
use super::tier::KernelTier;

/// Execute `kernel` with a whole-host thread budget. Convenience for
/// driver-side math, benches and tests; the executors call
/// [`execute_ctx`] with their per-worker budget instead.
pub fn execute(kernel: &Kernel, inputs: &[&Block]) -> Result<Vec<Block>> {
    execute_ctx(kernel, inputs, &ExecContext::host_default())
}

/// Execute `kernel` over real input blocks, producing real output blocks.
/// `ctx.kernel_threads` bounds the intra-kernel parallelism of the
/// compute-heavy kernels (matmul/gram/fused element-wise); everything else
/// is single-threaded regardless. `ctx.tier` selects the microkernel
/// implementation (blocked scalar vs packed-panel AVX2+FMA) — the tier
/// was resolved once at context construction, so this dispatch is a plain
/// enum match with no feature checks.
pub fn execute_ctx(kernel: &Kernel, inputs: &[&Block], ctx: &ExecContext) -> Result<Vec<Block>> {
    let t = ctx.kernel_threads;
    let tier = ctx.tier;
    let out = match kernel {
        Kernel::Neg => vec![map1(inputs[0], |v| -v)],
        Kernel::Sigmoid => vec![map1(inputs[0], |v| 1.0 / (1.0 + (-v).exp()))],
        Kernel::Scale(c) => {
            let c = *c;
            vec![map1(inputs[0], move |v| c * v)]
        }
        Kernel::Ew(op) => vec![map2(inputs[0], inputs[1], *op)?],
        Kernel::FusedEw(steps) => vec![fused_ew(steps, inputs, t, tier)?],
        Kernel::Matmul => vec![dense::matmul_tier(inputs[0], inputs[1], 1.0, t, tier)],
        // lazy transpose of the (usually much smaller) right operand, then
        // the tiered kernel
        Kernel::MatmulNT => {
            vec![dense::matmul_tier(inputs[0], &inputs[1].transposed(), 1.0, t, tier)]
        }
        // Aᵀ·B without materializing the transposed block (streamed in the
        // scalar tier, packed Aᵀ strips in the SIMD tier)
        Kernel::Gram => vec![dense::gram_tier(inputs[0], inputs[1], 1.0, t, tier)],
        // contractions with a folded Scale/Neg epilogue: α is applied in
        // the C-writeback (Simd) or one output sweep (Scalar), never as a
        // separate task
        Kernel::ScaledMatmul(al) => vec![dense::matmul_tier(inputs[0], inputs[1], *al, t, tier)],
        Kernel::ScaledMatmulNT(al) => {
            vec![dense::matmul_tier(inputs[0], &inputs[1].transposed(), *al, t, tier)]
        }
        Kernel::ScaledGram(al) => vec![dense::gram_tier(inputs[0], inputs[1], *al, t, tier)],
        Kernel::SumAxis0 => vec![sum_axis0(inputs[0])],
        Kernel::SumAxis1 => vec![sum_axis1(inputs[0])],
        Kernel::SumAll => {
            let s: f64 = inputs[0].buf().iter().sum();
            vec![Block::from_vec(&[1, 1], vec![s])]
        }
        Kernel::GlmMu | Kernel::PredictBlock => vec![glm_mu(inputs[0], inputs[1], tier)],
        Kernel::GlmGrad => vec![glm_grad(inputs[0], inputs[1], inputs[2], tier)],
        Kernel::GlmHess => vec![glm_hess(inputs[0], inputs[1], tier)],
        Kernel::LogLoss => vec![logloss(inputs[0], inputs[1])],
        Kernel::NewtonBlock => {
            let (x, y, beta) = (inputs[0], inputs[1], inputs[2]);
            let mu = glm_mu(x, beta, tier);
            let outs = vec![
                glm_grad(x, &mu, y, tier),
                glm_hess(x, &mu, tier),
                logloss(&mu, y),
            ];
            pool::recycle(mu.into_vec());
            outs
        }
        Kernel::LbfgsBlock => {
            let (x, y, beta) = (inputs[0], inputs[1], inputs[2]);
            let mu = glm_mu(x, beta, tier);
            let outs = vec![glm_grad(x, &mu, y, tier), logloss(&mu, y)];
            pool::recycle(mu.into_vec());
            outs
        }
        Kernel::Qr => {
            let (q, r) = dense::householder_qr(inputs[0]);
            vec![q, r]
        }
        Kernel::StackQr => {
            let stacked = inputs[0].vstack(inputs[1]);
            let (q, r) = dense::householder_qr(&stacked);
            vec![q, r]
        }
        Kernel::SplitTop => {
            let d = inputs[0].cols();
            vec![inputs[0].slice_rows(0, d)]
        }
        Kernel::SplitBottom => {
            let d = inputs[0].cols();
            vec![inputs[0].slice_rows(d, 2 * d)]
        }
        Kernel::InvUpper => vec![dense::inv_upper(inputs[0])],
        Kernel::Cholesky => vec![dense::cholesky(inputs[0])],
        Kernel::SolveSpd => vec![dense::solve_spd(inputs[0], inputs[1], 1e-10)],
        Kernel::Transpose => vec![inputs[0].transposed()],
        Kernel::ColScale => {
            let (x, w) = (inputs[0], inputs[1]);
            let (m, d) = (x.rows(), x.cols());
            assert_eq!(w.shape, vec![m, 1]);
            let (xb, wb) = (x.buf(), w.buf());
            let mut out = vec![0.0; m * d];
            for i in 0..m {
                let wi = wb[i];
                for j in 0..d {
                    out[i * d + j] = wi * xb[i * d + j];
                }
            }
            vec![Block::from_vec(&[m, d], out)]
        }
        Kernel::MttkrpTerm => vec![mttkrp_term(inputs[0], inputs[1], inputs[2])],
        Kernel::TensordotJK => vec![tensordot_jk(inputs[0], inputs[1])],
        Kernel::EinsumXB => vec![einsum_xb(inputs[0], inputs[1])],
        Kernel::EinsumWC => vec![einsum_wc(inputs[0], inputs[1])],
    };
    // sanity: shapes must match the kernel contract
    let want = kernel.out_shapes(&inputs.iter().map(|b| b.shape.clone()).collect::<Vec<_>>());
    for (o, w) in out.iter().zip(&want) {
        if &o.shape != w {
            bail!("{kernel}: produced {:?}, contract says {:?}", o.shape, w);
        }
    }
    Ok(out)
}

fn map1(x: &Block, f: impl Fn(f64) -> f64) -> Block {
    Block::from_vec(&x.shape, x.buf().iter().map(|&v| f(v)).collect())
}

/// Elements per fused-interpreter chunk: steps run back-to-back on a
/// slice that stays in L1 while the whole block is traversed once.
const FUSED_CHUNK: usize = 4096;

/// Below this many elements a fused chain stays single-threaded (it is
/// bandwidth-bound; spawning threads for small blocks only adds latency).
const FUSED_PAR_MIN: usize = 1 << 16;

/// Single-pass interpreter for [`Kernel::FusedEw`]: one pool-backed
/// accumulator buffer, zero intermediate blocks. Applies each step with
/// exactly the scalar expression the unfused kernel uses, so fused results
/// are bit-for-bit identical to the op-by-op oracle. Large blocks split
/// into disjoint element ranges across up to `threads` workers — each
/// element's value never depends on the split, so results are also
/// bit-identical across thread counts. The Simd tier runs the add/mul/
/// scale/neg segments through lane-exact AVX2 ops (no FMA), so the
/// bit-identity contract holds in *both* tiers; Sigmoid stays scalar per
/// element in every tier.
fn fused_ew(steps: &[EwStep], inputs: &[&Block], threads: usize, tier: KernelTier) -> Result<Block> {
    if inputs.is_empty() {
        bail!("fused_ew: no inputs");
    }
    let shape = inputs[0].shape.clone();
    for b in &inputs[1..] {
        if b.shape != shape {
            bail!("fused_ew shape mismatch {:?} vs {shape:?}", b.shape);
        }
    }
    // map each binary step to the input slot it consumes
    let mut slot = 1usize;
    let plan: Vec<usize> = steps
        .iter()
        .map(|s| {
            if s.consumes_input() {
                slot += 1;
                slot - 1
            } else {
                0 // unused for unary steps
            }
        })
        .collect();
    if slot != inputs.len() {
        bail!(
            "fused_ew arity: {} inputs for {} binary steps",
            inputs.len(),
            slot - 1
        );
    }

    let n: usize = shape.iter().product();
    let mut out = pool::alloc_copy(inputs[0].buf());
    let t = if n >= FUSED_PAR_MIN && threads > 1 {
        threads.min(n / FUSED_CHUNK).max(1)
    } else {
        1
    };
    if t <= 1 {
        fused_ew_range(steps, &plan, inputs, &mut out, 0, tier);
    } else {
        let per = n / t + usize::from(n % t != 0);
        let plan = &plan;
        std::thread::scope(|scope| {
            for (ci, chunk) in out.chunks_mut(per).enumerate() {
                scope.spawn(move || fused_ew_range(steps, plan, inputs, chunk, ci * per, tier));
            }
        });
    }
    Ok(Block::from_vec(&shape, out))
}

/// Apply the fused chain to `out` (which holds elements `[base,
/// base+out.len())` of input 0's copy), reading the side inputs at the
/// same absolute offsets.
fn fused_ew_range(
    steps: &[EwStep],
    plan: &[usize],
    inputs: &[&Block],
    out: &mut [f64],
    base: usize,
    tier: KernelTier,
) {
    let simd = tier == KernelTier::Simd;
    let n = out.len();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + FUSED_CHUNK).min(n);
        for (step, &inp) in steps.iter().zip(plan) {
            let seg = &mut out[lo..hi];
            match *step {
                EwStep::Neg if simd => microkernel::neg_segment(seg),
                EwStep::Neg => {
                    for v in seg {
                        *v = -*v;
                    }
                }
                // sigmoid stays scalar per lane in every tier (exp has no
                // lane-exact vector form here)
                EwStep::Sigmoid => {
                    for v in seg {
                        *v = 1.0 / (1.0 + (-*v).exp());
                    }
                }
                EwStep::Scale(c) if simd => microkernel::scale_segment(seg, c),
                EwStep::Scale(c) => {
                    for v in seg {
                        *v = c * *v;
                    }
                }
                EwStep::Bin(op) if simd => microkernel::bin_segment_simd(
                    seg,
                    &inputs[inp].buf()[base + lo..base + hi],
                    op,
                    false,
                ),
                EwStep::Bin(op) => {
                    bin_segment(seg, &inputs[inp].buf()[base + lo..base + hi], op, false)
                }
                EwStep::BinRev(op) if simd => microkernel::bin_segment_simd(
                    seg,
                    &inputs[inp].buf()[base + lo..base + hi],
                    op,
                    true,
                ),
                EwStep::BinRev(op) => {
                    bin_segment(seg, &inputs[inp].buf()[base + lo..base + hi], op, true)
                }
            }
        }
        lo = hi;
    }
}

/// acc ∘= rhs (or rhs ∘ acc when `rev`), matching `map2`'s scalar forms.
fn bin_segment(acc: &mut [f64], rhs: &[f64], op: BinOp, rev: bool) {
    for (a, &b) in acc.iter_mut().zip(rhs) {
        let (x, y) = if rev { (b, *a) } else { (*a, b) };
        *a = match op {
            BinOp::Add => x + y,
            BinOp::Sub => x - y,
            BinOp::Mul => x * y,
            BinOp::Div => x / y,
        };
    }
}

fn map2(x: &Block, y: &Block, op: BinOp) -> Result<Block> {
    if x.shape != y.shape {
        bail!("ew shape mismatch {:?} vs {:?}", x.shape, y.shape);
    }
    let f = |a: f64, b: f64| match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
    };
    Ok(Block::from_vec(
        &x.shape,
        x.buf().iter().zip(y.buf()).map(|(&a, &b)| f(a, b)).collect(),
    ))
}

fn sum_axis0(x: &Block) -> Block {
    let (m, n) = (x.rows(), x.cols());
    let mut out = vec![0.0; n];
    let b = x.buf();
    for i in 0..m {
        for j in 0..n {
            out[j] += b[i * n + j];
        }
    }
    Block::from_vec(&[1, n], out)
}

fn sum_axis1(x: &Block) -> Block {
    let (m, n) = (x.rows(), x.cols());
    let mut out = vec![0.0; m];
    let b = x.buf();
    for i in 0..m {
        out[i] = b[i * n..(i + 1) * n].iter().sum();
    }
    Block::from_vec(&[m, 1], out)
}

fn glm_mu(x: &Block, beta: &Block, tier: KernelTier) -> Block {
    let (m, d) = (x.rows(), x.cols());
    assert_eq!(beta.shape, vec![d, 1]);
    let (xb, bb) = (x.buf(), beta.buf());
    let mut out = pool::alloc_zeroed(m);
    for i in 0..m {
        let z = if tier == KernelTier::Simd {
            microkernel::dot_fma(&xb[i * d..(i + 1) * d], bb)
        } else {
            let mut z = 0.0;
            for j in 0..d {
                z += xb[i * d + j] * bb[j];
            }
            z
        };
        out[i] = 1.0 / (1.0 + (-z).exp());
    }
    Block::from_vec(&[m, 1], out)
}

fn glm_grad(x: &Block, mu: &Block, y: &Block, tier: KernelTier) -> Block {
    let (m, d) = (x.rows(), x.cols());
    let (xb, mb, yb) = (x.buf(), mu.buf(), y.buf());
    let mut out = vec![0.0; d];
    for i in 0..m {
        let r = mb[i] - yb[i];
        if tier == KernelTier::Simd {
            microkernel::axpy_fma(&mut out, r, &xb[i * d..(i + 1) * d]);
        } else {
            for j in 0..d {
                out[j] += xb[i * d + j] * r;
            }
        }
    }
    Block::from_vec(&[d, 1], out)
}

fn glm_hess(x: &Block, mu: &Block, tier: KernelTier) -> Block {
    let (m, d) = (x.rows(), x.cols());
    let (xb, mb) = (x.buf(), mu.buf());
    let mut out = pool::alloc_zeroed(d * d);
    for i in 0..m {
        let w = mb[i] * (1.0 - mb[i]);
        let row = &xb[i * d..(i + 1) * d];
        for a in 0..d {
            let wa = w * row[a];
            if tier == KernelTier::Simd {
                microkernel::axpy_fma(&mut out[a * d..(a + 1) * d], wa, row);
            } else {
                for b in 0..d {
                    out[a * d + b] += wa * row[b];
                }
            }
        }
    }
    Block::from_vec(&[d, d], out)
}

const LOGLOSS_EPS: f64 = 1e-12;

fn logloss(mu: &Block, y: &Block) -> Block {
    let mut s = 0.0;
    for (&m, &yy) in mu.buf().iter().zip(y.buf()) {
        let m = m.clamp(LOGLOSS_EPS, 1.0 - LOGLOSS_EPS);
        s -= yy * m.ln() + (1.0 - yy) * (1.0 - m).ln();
    }
    Block::from_vec(&[1, 1], vec![s])
}

/// out[a,f] = Σ_{b,c} X[a,b,c] · B[b,f] · C[c,f] — the MTTKRP block term
/// for `einsum("ijk,jf,kf->if")` (§8.4).
fn mttkrp_term(x: &Block, bm: &Block, cm: &Block) -> Block {
    let (a, b, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let f = bm.shape[1];
    assert_eq!(bm.shape, vec![b, f]);
    assert_eq!(cm.shape, vec![c, f]);
    let (xb, bb, cb) = (x.buf(), bm.buf(), cm.buf());
    let mut out = vec![0.0; a * f];
    // contract c first: T[a,b,f] implicit — loop order keeps C rows hot
    for ia in 0..a {
        for ib in 0..b {
            let xrow = &xb[(ia * b + ib) * c..(ia * b + ib + 1) * c];
            let brow = &bb[ib * f..(ib + 1) * f];
            let orow = &mut out[ia * f..(ia + 1) * f];
            for (ic, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let crow = &cb[ic * f..(ic + 1) * f];
                for jf in 0..f {
                    orow[jf] += xv * brow[jf] * crow[jf];
                }
            }
        }
    }
    Block::from_vec(&[a, f], out)
}

/// W[a,c,f] = Σ_b X[a,b,c] · B[b,f] — stage 1 of the materializing
/// pairwise einsum baseline (Fig. 13a's Dask Arrays behaviour).
fn einsum_xb(x: &Block, bm: &Block) -> Block {
    let (a, b, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let f = bm.shape[1];
    assert_eq!(bm.shape[0], b);
    let (xb, bb) = (x.buf(), bm.buf());
    let mut out = vec![0.0; a * c * f];
    for ia in 0..a {
        for ib in 0..b {
            let brow = &bb[ib * f..(ib + 1) * f];
            for ic in 0..c {
                let xv = xb[(ia * b + ib) * c + ic];
                if xv == 0.0 {
                    continue;
                }
                let orow = &mut out[(ia * c + ic) * f..(ia * c + ic + 1) * f];
                for jf in 0..f {
                    orow[jf] += xv * brow[jf];
                }
            }
        }
    }
    Block::from_vec(&[a, c, f], out)
}

/// out[a,f] = Σ_c W[a,c,f] · C[c,f] — stage 2 of the pairwise einsum.
fn einsum_wc(w: &Block, cm: &Block) -> Block {
    let (a, c, f) = (w.shape[0], w.shape[1], w.shape[2]);
    assert_eq!(cm.shape, vec![c, f]);
    let (wb, cb) = (w.buf(), cm.buf());
    let mut out = vec![0.0; a * f];
    for ia in 0..a {
        let orow = &mut out[ia * f..(ia + 1) * f];
        for ic in 0..c {
            let wrow = &wb[(ia * c + ic) * f..(ia * c + ic + 1) * f];
            let crow = &cb[ic * f..(ic + 1) * f];
            for jf in 0..f {
                orow[jf] += wrow[jf] * crow[jf];
            }
        }
    }
    Block::from_vec(&[a, f], out)
}

/// out[a,f] = Σ_{b,c} X[a,b,c] · Y[b,c,f] — tensor double contraction.
fn tensordot_jk(x: &Block, y: &Block) -> Block {
    let (a, b, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let f = y.shape[2];
    assert_eq!(&y.shape[..2], &[b, c]);
    let (xb, yb) = (x.buf(), y.buf());
    let mut out = vec![0.0; a * f];
    for ia in 0..a {
        let orow = &mut out[ia * f..(ia + 1) * f];
        for ib in 0..b {
            for ic in 0..c {
                let xv = xb[(ia * b + ib) * c + ic];
                if xv == 0.0 {
                    continue;
                }
                let yrow = &yb[(ib * c + ic) * f..(ib * c + ic + 1) * f];
                for jf in 0..f {
                    orow[jf] += xv * yrow[jf];
                }
            }
        }
    }
    Block::from_vec(&[a, f], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(shape: &[usize], seed: u64) -> Block {
        let mut rng = Rng::seed_from_u64(seed);
        let n: usize = shape.iter().product();
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        Block::from_vec(shape, v)
    }

    #[test]
    fn ew_ops() {
        let a = Block::from_vec(&[1, 3], vec![1., 2., 3.]);
        let b = Block::from_vec(&[1, 3], vec![4., 5., 6.]);
        let sum = execute(&Kernel::Ew(BinOp::Add), &[&a, &b]).unwrap();
        assert_eq!(sum[0].buf(), &[5., 7., 9.]);
        let neg = execute(&Kernel::Neg, &[&a]).unwrap();
        assert_eq!(neg[0].buf(), &[-1., -2., -3.]);
    }

    #[test]
    fn fused_ew_matches_op_by_op_oracle() {
        // sigmoid(((-x) * 2 + y) / z) — crosses a chunk boundary (n > 4096)
        let x = randn(&[3, 2048], 21);
        let y = randn(&[3, 2048], 22);
        let z = map1(&randn(&[3, 2048], 23), |v| v.abs() + 1.0);
        let steps = vec![
            EwStep::Neg,
            EwStep::Scale(2.0),
            EwStep::Bin(BinOp::Add),
            EwStep::Bin(BinOp::Div),
            EwStep::Sigmoid,
        ];
        let fused = execute(&Kernel::FusedEw(steps), &[&x, &y, &z])
            .unwrap()
            .remove(0);
        let s1 = execute(&Kernel::Neg, &[&x]).unwrap().remove(0);
        let s2 = execute(&Kernel::Scale(2.0), &[&s1]).unwrap().remove(0);
        let s3 = execute(&Kernel::Ew(BinOp::Add), &[&s2, &y]).unwrap().remove(0);
        let s4 = execute(&Kernel::Ew(BinOp::Div), &[&s3, &z]).unwrap().remove(0);
        let want = execute(&Kernel::Sigmoid, &[&s4]).unwrap().remove(0);
        assert_eq!(fused.shape, want.shape);
        assert_eq!(fused.max_abs_diff(&want), 0.0, "fusion must be bit-exact");
    }

    #[test]
    fn fused_ew_rev_step_swaps_operands() {
        // y - (-x) via BinRev(Sub) with the chain as right operand
        let x = Block::from_vec(&[1, 3], vec![1., 2., 3.]);
        let y = Block::from_vec(&[1, 3], vec![10., 10., 10.]);
        let fused = execute(
            &Kernel::FusedEw(vec![EwStep::Neg, EwStep::BinRev(BinOp::Sub)]),
            &[&x, &y],
        )
        .unwrap()
        .remove(0);
        assert_eq!(fused.buf(), &[11., 12., 13.]);
    }

    #[test]
    fn fused_ew_rejects_bad_arity() {
        let x = Block::from_vec(&[1, 2], vec![1., 2.]);
        let err = fused_ew(&[EwStep::Bin(BinOp::Add)], &[&x], 1, KernelTier::detect()).unwrap_err();
        assert!(format!("{err}").contains("arity"));
    }

    #[test]
    fn contraction_variants_agree_with_transpose() {
        let x = randn(&[7, 4], 1);
        let y = randn(&[7, 5], 2);
        let g = execute(&Kernel::Gram, &[&x, &y]).unwrap();
        let manual = dense::matmul(&x.transposed(), &y);
        assert!(g[0].max_abs_diff(&manual) < 1e-12);

        let z = randn(&[6, 4], 3);
        let nt = execute(&Kernel::MatmulNT, &[&x, &z]).unwrap();
        let manual = dense::matmul(&x, &z.transposed());
        assert!(nt[0].max_abs_diff(&manual) < 1e-12);
    }

    #[test]
    fn glm_kernels_consistent_with_composites() {
        let x = randn(&[40, 5], 4);
        let y = map1(&randn(&[40, 1], 5), |v| if v > 0.0 { 1.0 } else { 0.0 });
        let beta = map1(&randn(&[5, 1], 6), |v| 0.1 * v);
        let mu = execute(&Kernel::GlmMu, &[&x, &beta]).unwrap().remove(0);
        let g = execute(&Kernel::GlmGrad, &[&x, &mu, &y]).unwrap().remove(0);
        let h = execute(&Kernel::GlmHess, &[&x, &mu]).unwrap().remove(0);
        let l = execute(&Kernel::LogLoss, &[&mu, &y]).unwrap().remove(0);
        let fused = execute(&Kernel::NewtonBlock, &[&x, &y, &beta]).unwrap();
        assert!(fused[0].max_abs_diff(&g) < 1e-12);
        assert!(fused[1].max_abs_diff(&h) < 1e-12);
        assert!(fused[2].max_abs_diff(&l) < 1e-12);
    }

    #[test]
    fn qr_and_stack_qr() {
        let x = randn(&[32, 4], 7);
        let out = execute(&Kernel::Qr, &[&x]).unwrap();
        let back = dense::matmul(&out[0], &out[1]);
        assert!(back.max_abs_diff(&x) < 1e-10);

        let ra = out[1].clone();
        let (_, rb) = dense::householder_qr(&randn(&[32, 4], 8));
        let sq = execute(&Kernel::StackQr, &[&ra, &rb]).unwrap();
        let back = dense::matmul(&sq[0], &sq[1]);
        assert!(back.max_abs_diff(&ra.vstack(&rb)) < 1e-10);
        let top = execute(&Kernel::SplitTop, &[&sq[0]]).unwrap();
        let bot = execute(&Kernel::SplitBottom, &[&sq[0]]).unwrap();
        assert_eq!(top[0].shape, vec![4, 4]);
        assert_eq!(bot[0].shape, vec![4, 4]);
    }

    #[test]
    fn mttkrp_matches_naive() {
        let x = randn(&[3, 4, 5], 9);
        let b = randn(&[4, 6], 10);
        let c = randn(&[5, 6], 11);
        let got = execute(&Kernel::MttkrpTerm, &[&x, &b, &c]).unwrap().remove(0);
        let mut want = vec![0.0; 3 * 6];
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    for f in 0..6 {
                        want[i * 6 + f] += x.buf()[(i * 4 + j) * 5 + k]
                            * b.buf()[j * 6 + f]
                            * c.buf()[k * 6 + f];
                    }
                }
            }
        }
        assert!(crate::util::stats::max_abs_diff(got.buf(), &want) < 1e-12);
    }

    #[test]
    fn tensordot_matches_naive() {
        let x = randn(&[3, 4, 5], 12);
        let y = randn(&[4, 5, 7], 13);
        let got = execute(&Kernel::TensordotJK, &[&x, &y]).unwrap().remove(0);
        let mut want = vec![0.0; 3 * 7];
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    for f in 0..7 {
                        want[i * 7 + f] +=
                            x.buf()[(i * 4 + j) * 5 + k] * y.buf()[(j * 5 + k) * 7 + f];
                    }
                }
            }
        }
        assert!(crate::util::stats::max_abs_diff(got.buf(), &want) < 1e-12);
    }

    #[test]
    fn reductions() {
        let x = Block::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(
            execute(&Kernel::SumAxis0, &[&x]).unwrap()[0].buf(),
            &[5., 7., 9.]
        );
        assert_eq!(
            execute(&Kernel::SumAxis1, &[&x]).unwrap()[0].buf(),
            &[6., 15.]
        );
        assert_eq!(execute(&Kernel::SumAll, &[&x]).unwrap()[0].buf(), &[21.]);
    }
}
