//! Loader for `artifacts/manifest.tsv` written by `python/compile/aot.py`.
//!
//! Each row describes one AOT-lowered (kernel, shape) artifact:
//! `name \t dims \t file \t n_outputs \t input_shapes \t output_shapes`
//! where shape lists are `;`-separated `x`-joined dims. Entries are indexed
//! by `(name, input_shapes)` — exactly what the runtime knows at dispatch
//! time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub dims: Vec<usize>,
    pub file: PathBuf,
    pub n_outputs: usize,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

#[derive(Default, Debug)]
pub struct Manifest {
    /// (kernel name, input shapes) -> entry
    by_sig: HashMap<(String, Vec<Vec<usize>>), ManifestEntry>,
    pub dir: PathBuf,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|t| t.parse::<usize>().with_context(|| format!("bad dim {t:?}")))
        .collect()
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(';').map(parse_shape).collect()
}

impl Manifest {
    /// Load `dir/manifest.tsv`. Missing manifest is an error — callers that
    /// want optional PJRT use [`Manifest::load_optional`].
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut m = Manifest {
            by_sig: HashMap::new(),
            dir: dir.clone(),
        };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                bail!("manifest line {} has {} cols, want 6", lineno + 1, cols.len());
            }
            let entry = ManifestEntry {
                name: cols[0].to_string(),
                dims: parse_shape(cols[1])?,
                file: dir.join(cols[2]),
                n_outputs: cols[3].parse().context("n_outputs")?,
                input_shapes: parse_shapes(cols[4])?,
                output_shapes: parse_shapes(cols[5])?,
            };
            if entry.n_outputs != entry.output_shapes.len() {
                bail!("manifest line {}: output arity mismatch", lineno + 1);
            }
            m.by_sig
                .insert((entry.name.clone(), entry.input_shapes.clone()), entry);
        }
        Ok(m)
    }

    /// Load if present; empty manifest otherwise.
    pub fn load_optional(dir: impl AsRef<Path>) -> Self {
        Self::load(&dir).unwrap_or_else(|_| Manifest {
            by_sig: HashMap::new(),
            dir: dir.as_ref().to_path_buf(),
        })
    }

    pub fn lookup(&self, name: &str, input_shapes: &[Vec<usize>]) -> Option<&ManifestEntry> {
        self.by_sig
            .get(&(name.to_string(), input_shapes.to_vec()))
    }

    pub fn len(&self) -> usize {
        self.by_sig.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_sig.is_empty()
    }

    pub fn entries(&self) -> impl Iterator<Item = &ManifestEntry> {
        self.by_sig.values()
    }

    /// Default artifacts directory: `$NUMS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("NUMS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.tsv")).unwrap();
        writeln!(f, "# header").unwrap();
        write!(f, "{body}").unwrap();
    }

    #[test]
    fn parses_rows_and_lookups() {
        let dir = std::env::temp_dir().join(format!("nums_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "add\t64x64\tadd_64x64.hlo.txt\t1\t64x64;64x64\t64x64\n\
             newton_block\t512x8\tnb.hlo.txt\t3\t512x8;512x1;8x1\t8x1;8x8;1x1\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        let e = m
            .lookup("add", &[vec![64, 64], vec![64, 64]])
            .expect("add entry");
        assert_eq!(e.n_outputs, 1);
        let nb = m
            .lookup("newton_block", &[vec![512, 8], vec![512, 1], vec![8, 1]])
            .expect("newton entry");
        assert_eq!(nb.output_shapes, vec![vec![8, 1], vec![8, 8], vec![1, 1]]);
        assert!(m.lookup("add", &[vec![3, 3], vec![3, 3]]).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_optional_tolerates_missing() {
        let m = Manifest::load_optional("/nonexistent/nowhere");
        assert!(m.is_empty());
    }

    #[test]
    fn rejects_malformed_rows() {
        let dir = std::env::temp_dir().join(format!("nums_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, "add\t64x64\tf.hlo\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
