//! The block-kernel vocabulary of the system.
//!
//! Every block-level task executes one [`Kernel`]. Each kernel knows its
//! output shapes (given input shapes), its cost model for the simulated
//! executor (FLOPs / element traffic), and — when an AOT artifact exists —
//! the manifest name used to find the PJRT executable lowered by
//! `python/compile/aot.py`.

use std::fmt;

/// Element-wise binary micro-op used by reduce trees and GraphArray.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

/// One step of a fused element-wise program ([`Kernel::FusedEw`]).
///
/// A program runs over a single accumulator seeded from input 0; each
/// `Bin`/`BinRev` step consumes the next unconsumed input, in order.
/// `BinRev` applies the operands swapped (`input ∘ acc`), which preserves
/// operand order for non-commutative ops when the fused chain arrives as
/// the *right* child of a binary vertex.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EwStep {
    Neg,
    Sigmoid,
    Scale(f64),
    Bin(BinOp),
    BinRev(BinOp),
}

impl EwStep {
    /// Whether this step consumes one additional input block.
    pub fn consumes_input(&self) -> bool {
        matches!(self, EwStep::Bin(_) | EwStep::BinRev(_))
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Kernel {
    // --- element-wise (1 output) ---
    Neg,
    Sigmoid,
    Scale(f64),
    Ew(BinOp),
    /// A fused chain of element-wise steps (`graph::fuse`): one task, one
    /// output block, zero materialized intermediates. This is App. A.1's
    /// communication-free chain made overhead-free as well — the native
    /// backend interprets the program in a single pass over one buffer.
    FusedEw(Vec<EwStep>),
    // --- contractions (1 output) ---
    /// A[m,k] @ B[k,n]
    Matmul,
    /// A[m,k] @ B[n,k]^T (lazy-transpose outer product)
    MatmulNT,
    /// A[k,m]^T @ B[k,n] (lazy-transpose inner product / Gram)
    Gram,
    /// α · (A @ B): a contraction with a `Scale`/`Neg` epilogue folded in
    /// by `graph::fuse::fuse_epilogues` — α is applied during the
    /// microkernel's C-writeback (Simd tier) or as one sweep (Scalar
    /// tier), never as a separate task over a materialized intermediate.
    ScaledMatmul(f64),
    /// α · (A @ Bᵀ) (see [`Kernel::ScaledMatmul`])
    ScaledMatmulNT(f64),
    /// α · (Aᵀ @ B) (see [`Kernel::ScaledMatmul`])
    ScaledGram(f64),
    // --- reductions over one block (1 output) ---
    SumAxis0,
    SumAxis1,
    SumAll,
    // --- fused GLM kernels (L1) ---
    GlmMu,
    GlmGrad,
    GlmHess,
    LogLoss,
    // --- fused L2 composites ---
    /// (X[m,d], y[m,1], beta[d,1]) -> (g[d,1], H[d,d], loss[1,1])
    NewtonBlock,
    /// (X[m,d], y[m,1], beta[d,1]) -> (g[d,1], loss[1,1])
    LbfgsBlock,
    /// (X[m,d], beta[d,1]) -> mu[m,1]
    PredictBlock,
    // --- factorization kernels (native only; LAPACK substrate) ---
    /// X[m,n] -> (Q[m,n], R[n,n]) thin Householder QR
    Qr,
    /// (Ra[d,d], Rb[d,d]) -> (Q[2d,d], R[d,d]): QR of the stacked pair
    StackQr,
    /// Q[2d,d] -> top/bottom [d,d] half (TSQR Q back-propagation)
    SplitTop,
    SplitBottom,
    /// R[n,n] -> R^{-1} (indirect TSQR)
    InvUpper,
    /// A[n,n] SPD -> L[n,n]
    Cholesky,
    /// (H[d,d], g[d,1]) -> H^{-1} g with a tiny ridge (Newton step)
    SolveSpd,
    /// X[m,n] -> X^T[n,m] (only when fusion is impossible)
    Transpose,
    /// (X[m,d], w[m,1]) -> w ⊙ X (row-broadcast multiply; the unfused
    /// Dask-ML pipeline materializes this dataset-sized intermediate, §8.5)
    ColScale,
    // --- tensor algebra (native only) ---
    /// (X[a,b,c], B[b,f], C[c,f]) -> out[a,f]: block MTTKRP term (§8.4)
    MttkrpTerm,
    /// (X[a,b,c], Y[b,c,f]) -> out[a,f]: double contraction term (§8.4)
    TensordotJK,
    /// (X[a,b,c], B[b,f]) -> W[a,c,f]: stage 1 of a *materializing*
    /// pairwise einsum (the Dask-Arrays baseline of Fig. 13a, which
    /// contracts operands two at a time and materializes the F×-larger
    /// intermediate)
    EinsumXB,
    /// (W[a,c,f], C[c,f]) -> out[a,f]: stage 2 of the pairwise einsum
    EinsumWC,
}

impl Kernel {
    /// Number of outputs.
    pub fn n_outputs(&self) -> usize {
        match self {
            Kernel::NewtonBlock => 3,
            Kernel::LbfgsBlock | Kernel::Qr | Kernel::StackQr => 2,
            _ => 1,
        }
    }

    /// Output shapes given input shapes. Panics on arity/shape mismatch —
    /// graph construction must only emit well-formed tasks.
    pub fn out_shapes(&self, ins: &[Vec<usize>]) -> Vec<Vec<usize>> {
        let two = |s: &[Vec<usize>]| (s[0].clone(), s[1].clone());
        match self {
            Kernel::Neg | Kernel::Sigmoid | Kernel::Scale(_) => vec![ins[0].clone()],
            Kernel::Ew(_) => {
                let (a, b) = two(ins);
                assert_eq!(a, b, "ew shape mismatch {a:?} vs {b:?}");
                vec![a]
            }
            Kernel::FusedEw(steps) => {
                let binary = steps.iter().filter(|s| s.consumes_input()).count();
                assert_eq!(
                    ins.len(),
                    binary + 1,
                    "fused_ew arity: {} inputs for {binary} binary steps",
                    ins.len()
                );
                for s in &ins[1..] {
                    assert_eq!(s, &ins[0], "fused_ew shape mismatch {s:?} vs {:?}", ins[0]);
                }
                vec![ins[0].clone()]
            }
            Kernel::Matmul | Kernel::ScaledMatmul(_) => {
                assert_eq!(ins[0][1], ins[1][0], "matmul {:?} @ {:?}", ins[0], ins[1]);
                vec![vec![ins[0][0], ins[1][1]]]
            }
            Kernel::MatmulNT | Kernel::ScaledMatmulNT(_) => {
                assert_eq!(ins[0][1], ins[1][1], "matmul_nt {:?} {:?}", ins[0], ins[1]);
                vec![vec![ins[0][0], ins[1][0]]]
            }
            Kernel::Gram | Kernel::ScaledGram(_) => {
                assert_eq!(ins[0][0], ins[1][0], "gram {:?} {:?}", ins[0], ins[1]);
                vec![vec![ins[0][1], ins[1][1]]]
            }
            Kernel::SumAxis0 => vec![vec![1, ins[0][1]]],
            Kernel::SumAxis1 => vec![vec![ins[0][0], 1]],
            Kernel::SumAll => vec![vec![1, 1]],
            Kernel::GlmMu | Kernel::PredictBlock => vec![vec![ins[0][0], 1]],
            Kernel::GlmGrad => vec![vec![ins[0][1], 1]],
            Kernel::GlmHess => vec![vec![ins[0][1], ins[0][1]]],
            Kernel::LogLoss => vec![vec![1, 1]],
            Kernel::NewtonBlock => {
                let d = ins[0][1];
                vec![vec![d, 1], vec![d, d], vec![1, 1]]
            }
            Kernel::LbfgsBlock => {
                let d = ins[0][1];
                vec![vec![d, 1], vec![1, 1]]
            }
            Kernel::Qr => {
                let (m, n) = (ins[0][0], ins[0][1]);
                assert!(m >= n, "thin QR needs m >= n");
                vec![vec![m, n], vec![n, n]]
            }
            Kernel::StackQr => {
                let d = ins[0][0];
                assert_eq!(ins[0], ins[1], "StackQr wants equal square Rs");
                vec![vec![2 * d, d], vec![d, d]]
            }
            Kernel::SplitTop | Kernel::SplitBottom => {
                let d = ins[0][1];
                assert_eq!(ins[0][0], 2 * d);
                vec![vec![d, d]]
            }
            Kernel::InvUpper | Kernel::Cholesky => {
                assert_eq!(ins[0][0], ins[0][1]);
                vec![ins[0].clone()]
            }
            Kernel::SolveSpd => vec![ins[1].clone()],
            Kernel::Transpose => vec![vec![ins[0][1], ins[0][0]]],
            Kernel::ColScale => {
                assert_eq!(ins[1], vec![ins[0][0], 1], "colscale weight shape");
                vec![ins[0].clone()]
            }
            Kernel::MttkrpTerm => {
                let (a, b, c) = (ins[0][0], ins[0][1], ins[0][2]);
                let f = ins[1][1];
                assert_eq!(ins[1][0], b, "mttkrp B rows");
                assert_eq!(ins[2], vec![c, f], "mttkrp C shape");
                vec![vec![a, f]]
            }
            Kernel::TensordotJK => {
                let (a, b, c) = (ins[0][0], ins[0][1], ins[0][2]);
                let f = ins[1][2];
                assert_eq!(&ins[1][..2], &[b, c], "tensordot inner dims");
                vec![vec![a, f]]
            }
            Kernel::EinsumXB => {
                let (a, b, c) = (ins[0][0], ins[0][1], ins[0][2]);
                let f = ins[1][1];
                assert_eq!(ins[1][0], b, "einsum XB inner dim");
                vec![vec![a, c, f]]
            }
            Kernel::EinsumWC => {
                let (a, c, f) = (ins[0][0], ins[0][1], ins[0][2]);
                assert_eq!(ins[1], vec![c, f], "einsum WC shapes");
                vec![vec![a, f]]
            }
        }
    }

    /// Dense FLOP count for the cost model (contractions) — 0 for
    /// bandwidth-bound kernels, which are charged by element instead.
    pub fn flops(&self, ins: &[Vec<usize>]) -> f64 {
        let p = |s: &[usize]| s.iter().map(|&x| x as f64).product::<f64>();
        match self {
            Kernel::Matmul | Kernel::ScaledMatmul(_) => {
                2.0 * ins[0][0] as f64 * ins[0][1] as f64 * ins[1][1] as f64
            }
            Kernel::MatmulNT | Kernel::ScaledMatmulNT(_) => {
                2.0 * ins[0][0] as f64 * ins[0][1] as f64 * ins[1][0] as f64
            }
            Kernel::Gram | Kernel::ScaledGram(_) => {
                2.0 * ins[0][0] as f64 * ins[0][1] as f64 * ins[1][1] as f64
            }
            Kernel::GlmMu | Kernel::PredictBlock => 2.0 * p(&ins[0]),
            Kernel::GlmGrad => 2.0 * p(&ins[0]),
            Kernel::GlmHess => 2.0 * p(&ins[0]) * ins[0][1] as f64 / 2.0 + 2.0 * p(&ins[0]),
            Kernel::NewtonBlock => {
                // mu + grad + hess + loss
                let x = p(&ins[0]);
                2.0 * x + 2.0 * x + (x * ins[0][1] as f64 + 2.0 * x) + 8.0 * ins[0][0] as f64
            }
            Kernel::LbfgsBlock => 4.0 * p(&ins[0]) + 8.0 * ins[0][0] as f64,
            Kernel::Qr => 2.0 * ins[0][0] as f64 * (ins[0][1] as f64).powi(2),
            Kernel::StackQr => 4.0 * (ins[0][0] as f64).powi(3),
            Kernel::InvUpper | Kernel::Cholesky | Kernel::SolveSpd => {
                (ins[0][0] as f64).powi(3) / 3.0
            }
            Kernel::MttkrpTerm => 3.0 * p(&ins[0]) * ins[1][1] as f64,
            Kernel::TensordotJK => 2.0 * p(&ins[0]) * ins[1][2] as f64,
            Kernel::EinsumXB => 2.0 * p(&ins[0]) * ins[1][1] as f64,
            Kernel::EinsumWC => 3.0 * p(&ins[0]),
            _ => 0.0,
        }
    }

    /// Elements touched, for bandwidth-bound kernels.
    pub fn ew_elems(&self, ins: &[Vec<usize>]) -> f64 {
        let read: f64 = ins
            .iter()
            .map(|s| s.iter().map(|&x| x as f64).product::<f64>())
            .sum();
        match self {
            // Single-pass interpretation: each input is read once and the
            // accumulator written once — the unfused chain's intermediates
            // never touch memory, so a k-op chain costs (k+2)·B instead of
            // ~2k·B elements of traffic.
            Kernel::FusedEw(_) => {
                read + ins[0].iter().map(|&x| x as f64).product::<f64>()
            }
            _ => read,
        }
    }

    /// Manifest (AOT artifact) name, if this kernel has a Python builder.
    pub fn manifest_name(&self) -> Option<&'static str> {
        Some(match self {
            Kernel::Neg => "neg",
            Kernel::Sigmoid => "sigmoid",
            Kernel::Ew(BinOp::Add) => "add",
            Kernel::Ew(BinOp::Sub) => "sub",
            Kernel::Ew(BinOp::Mul) => "mul",
            Kernel::Ew(BinOp::Div) => "div",
            Kernel::Matmul => "matmul",
            Kernel::MatmulNT => "matmul_nt",
            Kernel::Gram => "gram",
            Kernel::SumAxis0 => "sum_axis0",
            Kernel::SumAxis1 => "sum_axis1",
            Kernel::SumAll => "sum_all",
            Kernel::GlmMu => "glm_mu",
            Kernel::GlmGrad => "glm_grad",
            Kernel::GlmHess => "glm_hess",
            Kernel::LogLoss => "logloss",
            Kernel::NewtonBlock => "newton_block",
            Kernel::LbfgsBlock => "lbfgs_block",
            Kernel::PredictBlock => "predict_block",
            _ => return None,
        })
    }

    /// Whether the cost model should charge FLOPs (compute-bound) rather
    /// than elements (bandwidth-bound).
    pub fn is_contraction(&self) -> bool {
        matches!(
            self,
            Kernel::Matmul
                | Kernel::MatmulNT
                | Kernel::Gram
                | Kernel::ScaledMatmul(_)
                | Kernel::ScaledMatmulNT(_)
                | Kernel::ScaledGram(_)
                | Kernel::GlmMu
                | Kernel::GlmGrad
                | Kernel::GlmHess
                | Kernel::NewtonBlock
                | Kernel::LbfgsBlock
                | Kernel::PredictBlock
                | Kernel::Qr
                | Kernel::StackQr
                | Kernel::InvUpper
                | Kernel::Cholesky
                | Kernel::SolveSpd
                | Kernel::MttkrpTerm
                | Kernel::TensordotJK
                | Kernel::EinsumXB
                | Kernel::EinsumWC
        )
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Kernel::FusedEw(steps) => return write!(f, "fused_ew[{}]", steps.len()),
            Kernel::ScaledMatmul(a) => return write!(f, "matmul·α[{a}]"),
            Kernel::ScaledMatmulNT(a) => return write!(f, "matmul_nt·α[{a}]"),
            Kernel::ScaledGram(a) => return write!(f, "gram·α[{a}]"),
            _ => {}
        }
        match self.manifest_name() {
            Some(n) => write!(f, "{n}"),
            None => write!(f, "{self:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_shapes_basic() {
        let k = Kernel::Matmul;
        assert_eq!(k.out_shapes(&[vec![4, 8], vec![8, 3]]), vec![vec![4, 3]]);
        assert_eq!(
            Kernel::Gram.out_shapes(&[vec![100, 4], vec![100, 6]]),
            vec![vec![4, 6]]
        );
        assert_eq!(
            Kernel::MatmulNT.out_shapes(&[vec![4, 8], vec![5, 8]]),
            vec![vec![4, 5]]
        );
    }

    #[test]
    fn multi_output_arity() {
        assert_eq!(Kernel::NewtonBlock.n_outputs(), 3);
        let outs = Kernel::NewtonBlock.out_shapes(&[vec![512, 8], vec![512, 1], vec![8, 1]]);
        assert_eq!(outs, vec![vec![8, 1], vec![8, 8], vec![1, 1]]);
        let qr = Kernel::Qr.out_shapes(&[vec![32, 4]]);
        assert_eq!(qr, vec![vec![32, 4], vec![4, 4]]);
        let sq = Kernel::StackQr.out_shapes(&[vec![4, 4], vec![4, 4]]);
        assert_eq!(sq, vec![vec![8, 4], vec![4, 4]]);
    }

    #[test]
    fn tensor_shapes() {
        assert_eq!(
            Kernel::MttkrpTerm.out_shapes(&[vec![4, 5, 6], vec![5, 10], vec![6, 10]]),
            vec![vec![4, 10]]
        );
        assert_eq!(
            Kernel::TensordotJK.out_shapes(&[vec![4, 5, 6], vec![5, 6, 10]]),
            vec![vec![4, 10]]
        );
    }

    #[test]
    fn scaled_contractions_share_the_base_contract() {
        let ins = vec![vec![4, 8], vec![8, 3]];
        let s = Kernel::ScaledMatmul(-2.0);
        assert_eq!(s.out_shapes(&ins), Kernel::Matmul.out_shapes(&ins));
        assert_eq!(s.flops(&ins), Kernel::Matmul.flops(&ins));
        assert!(s.is_contraction());
        assert_eq!(s.manifest_name(), None, "no AOT artifact: native-only");
        assert_eq!(format!("{s}"), "matmul·α[-2]");

        let g_ins = vec![vec![100, 4], vec![100, 6]];
        assert_eq!(
            Kernel::ScaledGram(0.5).out_shapes(&g_ins),
            Kernel::Gram.out_shapes(&g_ins)
        );
        let nt_ins = vec![vec![4, 8], vec![5, 8]];
        assert_eq!(
            Kernel::ScaledMatmulNT(3.0).out_shapes(&nt_ins),
            Kernel::MatmulNT.out_shapes(&nt_ins)
        );
        assert!(Kernel::ScaledGram(0.5).is_contraction());
        assert!(Kernel::ScaledMatmulNT(3.0).is_contraction());
    }

    #[test]
    fn flops_positive_for_contractions() {
        assert!(Kernel::Matmul.flops(&[vec![64, 64], vec![64, 64]]) > 0.0);
        assert_eq!(Kernel::Ew(BinOp::Add).flops(&[vec![64, 64], vec![64, 64]]), 0.0);
        assert!(Kernel::Matmul.is_contraction());
        assert!(!Kernel::Neg.is_contraction());
    }

    #[test]
    fn manifest_names() {
        assert_eq!(Kernel::Ew(BinOp::Add).manifest_name(), Some("add"));
        assert_eq!(Kernel::Qr.manifest_name(), None);
        assert_eq!(Kernel::NewtonBlock.manifest_name(), Some("newton_block"));
    }

    #[test]
    #[should_panic(expected = "matmul")]
    fn matmul_shape_mismatch_panics() {
        Kernel::Matmul.out_shapes(&[vec![4, 8], vec![7, 3]]);
    }

    #[test]
    fn fused_ew_contract() {
        let k = Kernel::FusedEw(vec![
            EwStep::Neg,
            EwStep::Bin(BinOp::Add),
            EwStep::Sigmoid,
            EwStep::BinRev(BinOp::Sub),
        ]);
        assert_eq!(k.n_outputs(), 1);
        assert!(!k.is_contraction());
        assert_eq!(k.manifest_name(), None);
        assert_eq!(format!("{k}"), "fused_ew[4]");
        let ins = vec![vec![8, 4], vec![8, 4], vec![8, 4]];
        assert_eq!(k.out_shapes(&ins), vec![vec![8, 4]]);
        assert_eq!(k.flops(&ins), 0.0);
        // single-pass traffic: 3 reads + 1 write of a 32-elem block ...
        assert_eq!(k.ew_elems(&ins), 4.0 * 32.0);
        // ... versus ~2 reads per op for the 4-task unfused chain
        let unfused = Kernel::Neg.ew_elems(&ins[..1])
            + Kernel::Ew(BinOp::Add).ew_elems(&ins[..2])
            + Kernel::Sigmoid.ew_elems(&ins[..1])
            + Kernel::Ew(BinOp::Sub).ew_elems(&ins[..2]);
        assert!(k.ew_elems(&ins) < unfused);
    }

    #[test]
    #[should_panic(expected = "fused_ew arity")]
    fn fused_ew_arity_mismatch_panics() {
        Kernel::FusedEw(vec![EwStep::Neg]).out_shapes(&[vec![2, 2], vec![2, 2]]);
    }

    #[test]
    #[should_panic(expected = "fused_ew shape mismatch")]
    fn fused_ew_shape_mismatch_panics() {
        Kernel::FusedEw(vec![EwStep::Bin(BinOp::Mul)])
            .out_shapes(&[vec![2, 2], vec![4, 1]]);
    }
}
