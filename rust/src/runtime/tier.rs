//! Kernel-tier selection: which microkernel implementation every
//! [`crate::runtime::Backend::execute`] path dispatches to.
//!
//! The paper's per-node DGEMM numbers assume each worker runs near
//! hardware peak; the blocked-scalar kernels in `linalg::dense` are
//! cache-friendly but leave the vector units idle. [`KernelTier`] is the
//! dispatch decision made **once at startup** — `is_x86_feature_detected!`
//! is never consulted on the kernel hot path. The resolved tier is
//! threaded through [`crate::runtime::ExecContext`], so executors, benches
//! and driver-side math all agree on which implementation runs.
//!
//! Tiers:
//!
//! * [`KernelTier::Scalar`] — the blocked, register-tiled scalar kernels.
//!   Bit-identical to `matmul_naive` and across thread counts; the oracle
//!   tier every property suite pins via `SessionConfig::strict_kernels`.
//! * [`KernelTier::Simd`] — packed-panel AVX2+FMA microkernels
//!   (`linalg::microkernel`). FMA contracts `a·b + c` with a single
//!   rounding, so contractions differ from the scalar tier by a bounded
//!   relative error (`tests/kernel_tier.rs`); element-wise kernels stay
//!   lane-exact (no FMA), so fusion bit-identity suites hold in both
//!   tiers.
//!
//! `NUMS_KERNEL_TIER` overrides detection process-wide: `scalar` forces
//! the portable tier everywhere (the CI fallback leg), `simd` requests
//! the vector tier (granted only when the host supports AVX2+FMA),
//! `auto`/unset means hardware detection. The variable is read once and
//! cached.

use std::sync::OnceLock;

/// Which kernel implementation a dispatch site should run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable blocked-scalar kernels (bit-stable oracle tier).
    Scalar,
    /// Packed-panel AVX2+FMA microkernels (epsilon-bounded contractions).
    Simd,
}

/// What `NUMS_KERNEL_TIER` asked for (parsed once, cached).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TierRequest {
    Scalar,
    Simd,
    Auto,
}

/// Parse one `NUMS_KERNEL_TIER` value. Pure — unit-tested directly.
fn parse_request(s: &str) -> Option<TierRequest> {
    match s.trim().to_ascii_lowercase().as_str() {
        "scalar" => Some(TierRequest::Scalar),
        "simd" => Some(TierRequest::Simd),
        "" | "auto" => Some(TierRequest::Auto),
        _ => None,
    }
}

fn env_request() -> TierRequest {
    static REQ: OnceLock<TierRequest> = OnceLock::new();
    *REQ.get_or_init(|| {
        std::env::var("NUMS_KERNEL_TIER")
            .ok()
            .and_then(|s| parse_request(&s))
            .unwrap_or(TierRequest::Auto)
    })
}

impl KernelTier {
    /// What the hardware can run: `Simd` only when the host has both AVX2
    /// and FMA (the microkernel uses `_mm256_fmadd_pd`).
    fn hardware() -> KernelTier {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return KernelTier::Simd;
            }
        }
        KernelTier::Scalar
    }

    /// The process-wide default tier: `NUMS_KERNEL_TIER` if set, hardware
    /// detection otherwise. Computed once, cached in a `OnceLock` — this
    /// is the value every default-constructed [`crate::runtime::ExecContext`]
    /// carries.
    pub fn detect() -> KernelTier {
        static TIER: OnceLock<KernelTier> = OnceLock::new();
        *TIER.get_or_init(|| match env_request() {
            TierRequest::Scalar => KernelTier::Scalar,
            // an explicit `simd` request still needs the hardware
            TierRequest::Simd | TierRequest::Auto => KernelTier::hardware(),
        })
    }

    /// The vector tier when the host supports it, scalar otherwise —
    /// ignores the env override. Used by the epsilon suite and benches to
    /// exercise the SIMD path explicitly.
    pub fn simd_if_available() -> KernelTier {
        KernelTier::hardware()
    }

    /// Resolve an explicit tier choice against the environment:
    /// `NUMS_KERNEL_TIER=scalar` is a global safety valve that wins over
    /// any request, and a `Simd` request is granted only on capable
    /// hardware. A `Scalar` request always sticks (correctness toggles
    /// like `SessionConfig::strict_kernels` beat the perf env knob).
    pub fn resolve(requested: KernelTier) -> KernelTier {
        if env_request() == TierRequest::Scalar {
            return KernelTier::Scalar;
        }
        match requested {
            KernelTier::Scalar => KernelTier::Scalar,
            KernelTier::Simd => KernelTier::hardware(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Simd => "simd",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_recognizes_the_documented_values() {
        assert_eq!(parse_request("scalar"), Some(TierRequest::Scalar));
        assert_eq!(parse_request("SIMD"), Some(TierRequest::Simd));
        assert_eq!(parse_request("auto"), Some(TierRequest::Auto));
        assert_eq!(parse_request(""), Some(TierRequest::Auto));
        assert_eq!(parse_request(" Scalar "), Some(TierRequest::Scalar));
        assert_eq!(parse_request("avx512"), None);
    }

    #[test]
    fn detect_is_stable_and_consistent() {
        // cached: repeated calls agree (the whole point — one decision,
        // no per-call feature checks)
        let t = KernelTier::detect();
        assert_eq!(KernelTier::detect(), t);
        // detect can only grant Simd where the hardware tier grants it
        if t == KernelTier::Simd {
            assert_eq!(KernelTier::simd_if_available(), KernelTier::Simd);
        }
    }

    #[test]
    fn resolve_honors_scalar_requests() {
        // a Scalar request is never upgraded, whatever the env says
        assert_eq!(KernelTier::resolve(KernelTier::Scalar), KernelTier::Scalar);
        // a Simd request is at most the hardware tier
        let r = KernelTier::resolve(KernelTier::Simd);
        assert!(r == KernelTier::simd_if_available() || r == KernelTier::Scalar);
    }

    #[test]
    fn names_round_trip_through_parse() {
        for t in [KernelTier::Scalar, KernelTier::Simd] {
            assert!(parse_request(t.name()).is_some());
        }
    }
}
