//! Kernel backend selection.
//!
//! [`Backend`] is what executors call. `Native` runs everything in-process;
//! `Pjrt` prefers AOT artifacts for supported (kernel, shape) pairs and
//! falls back to native for the rest (factorizations, odd shapes). The
//! composite keeps counters so benches can report the artifact hit-rate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::store::Block;

use super::exec_ctx::ExecContext;
use super::kernel::Kernel;
use super::native;
use super::pjrt::PjrtRuntime;

pub enum Backend {
    Native,
    Pjrt {
        rt: Arc<PjrtRuntime>,
        pjrt_hits: AtomicU64,
        native_falls: AtomicU64,
    },
}

impl Backend {
    pub fn native() -> Self {
        Backend::Native
    }

    /// PJRT-preferring backend over the given artifacts dir.
    pub fn pjrt(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Backend::Pjrt {
            rt: Arc::new(PjrtRuntime::new(dir)?),
            pjrt_hits: AtomicU64::new(0),
            native_falls: AtomicU64::new(0),
        })
    }

    /// PJRT over the default artifacts dir (`$NUMS_ARTIFACTS` or
    /// `./artifacts`), or native if artifacts are missing.
    pub fn auto() -> Self {
        let dir = super::manifest::Manifest::default_dir();
        match Self::pjrt(&dir) {
            Ok(b) => b,
            Err(_) => Backend::Native,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt { .. } => "pjrt+native",
        }
    }

    /// Execute a kernel over real blocks. `ctx` carries the intra-kernel
    /// thread budget and placement info; there is no global fallback — the
    /// caller decides how much of the machine this task may use.
    pub fn execute(&self, kernel: &Kernel, inputs: &[&Block], ctx: &ExecContext) -> Result<Vec<Block>> {
        match self {
            Backend::Native => native::execute_ctx(kernel, inputs, ctx),
            Backend::Pjrt {
                rt,
                pjrt_hits,
                native_falls,
            } => {
                let shapes: Vec<Vec<usize>> = inputs.iter().map(|b| b.shape.clone()).collect();
                if rt.supports(kernel, &shapes) {
                    pjrt_hits.fetch_add(1, Ordering::Relaxed);
                    rt.execute(kernel, inputs, ctx)
                } else {
                    native_falls.fetch_add(1, Ordering::Relaxed);
                    native::execute_ctx(kernel, inputs, ctx)
                }
            }
        }
    }

    /// (pjrt executions, native fallbacks) so far.
    pub fn counters(&self) -> (u64, u64) {
        match self {
            Backend::Native => (0, 0),
            Backend::Pjrt {
                pjrt_hits,
                native_falls,
                ..
            } => (
                pjrt_hits.load(Ordering::Relaxed),
                native_falls.load(Ordering::Relaxed),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernel::BinOp;

    #[test]
    fn native_backend_executes() {
        let b = Backend::native();
        let x = Block::from_vec(&[1, 2], vec![1., 2.]);
        let y = Block::from_vec(&[1, 2], vec![3., 4.]);
        let out = b
            .execute(&Kernel::Ew(BinOp::Add), &[&x, &y], &ExecContext::host_default())
            .unwrap();
        assert_eq!(out[0].buf(), &[4., 6.]);
        assert_eq!(b.counters(), (0, 0));
        assert_eq!(b.name(), "native");
    }
}
