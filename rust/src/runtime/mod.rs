//! Kernel runtime: the block-kernel vocabulary, the native (pure-Rust)
//! implementation, and the PJRT loader for AOT artifacts produced by
//! `python/compile/aot.py`.

pub mod backend;
pub mod exec_ctx;
pub mod kernel;
pub mod manifest;
pub mod native;
pub mod pjrt;
pub mod tier;

pub use backend::Backend;
pub use exec_ctx::ExecContext;
pub use kernel::{BinOp, EwStep, Kernel};
pub use tier::KernelTier;
pub use manifest::{Manifest, ManifestEntry};
pub use pjrt::PjrtRuntime;
