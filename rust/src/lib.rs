//! # NumS-RS — Scalable Array Programming for the Cloud (reproduction)
//!
//! A full-system reproduction of *NumS* (Elibol et al., 2022): distributed
//! NumPy-like arrays scheduled by **LSHS** (Load Simulated Hierarchical
//! Scheduling) over a task-based distributed system, built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3** (this crate): GraphArrays, LSHS, baseline schedulers, the
//!   cluster simulator, GLMs, TSQR, tensor algebra, SUMMA, benches.
//! * **L2/L1** (`python/compile`): JAX block-compute graphs and Pallas
//!   kernels, AOT-lowered once to HLO text.
//! * **Runtime**: the `xla` crate's PJRT CPU client loads and executes the
//!   artifacts on the request path; Python is never invoked at runtime.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.

pub mod api;
pub mod bench;
pub mod exec;
pub mod glm;
pub mod graph;
pub mod grid;
pub mod io;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scheduler;
pub mod store;
pub mod summa;
pub mod tensor;
pub mod util;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::api::{ExecMode, Policy, Session, SessionConfig};
    pub use crate::graph::{build, DistArray, Graph};
    pub use crate::grid::{ArrayGrid, NodeGrid};
    pub use crate::net::model::{ComputeParams, NetParams, SystemMode};
    pub use crate::runtime::{Backend, BinOp, EwStep, Kernel};
    pub use crate::scheduler::{ClusterState, Lshs, Topology};
    pub use crate::store::Block;
    pub use crate::util::rng::Rng;
}
