//! # NumS-RS — Scalable Array Programming for the Cloud (reproduction)
//!
//! A full-system reproduction of *NumS* (Elibol et al., 2022): distributed
//! NumPy-like arrays scheduled by **LSHS** (Load Simulated Hierarchical
//! Scheduling) over a task-based distributed system, built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3** (this crate): GraphArrays, LSHS, baseline schedulers, the
//!   cluster simulator, GLMs, TSQR, tensor algebra, SUMMA, benches.
//! * **L2/L1** (`python/compile`): JAX block-compute graphs and Pallas
//!   kernels, AOT-lowered once to HLO text.
//! * **Runtime**: the `xla` crate's PJRT CPU client loads and executes the
//!   artifacts on the request path; Python is never invoked at runtime.
//!
//! ## Execution model
//!
//! Real execution is dependency-counted and work-stealing
//! ([`exec::RealExecutor`]): tasks become runnable the moment their last
//! input is produced, idle workers steal ready tasks from other nodes
//! (paying the input transfers), and per-node
//! `(tasks_run, tasks_stolen, steal_bytes)` counters surface in
//! [`exec::RealReport`]. Steals are locality-aware (the victim whose next
//! task needs the fewest bytes pulled wins) and batched (a deeply-skewed
//! victim loses half its deque in one steal). `SessionConfig::stealing`
//! (default `true`) toggles stealing per session — `false` reproduces
//! strict node-affinity FIFO execution for ablations.
//!
//! Communication overlaps compute ([`exec::Prefetcher`],
//! `SessionConfig::prefetch`, default on): each node runs a transfer
//! thread that pulls the remote inputs of *near-ready* tasks — unmet
//! dependency count ≤ 1, using the scheduler's committed per-task
//! transfer decisions carried in the [`exec::Plan`] as source hints — so
//! by the time a worker dequeues a task its inputs are usually resident.
//! The transfer queues are priority queues ordered by the consumer
//! task's topological depth (next-to-run inputs move first), bounded by
//! a queued-pull byte budget derived from the memory budget, and a
//! steal *cancels* the victim's queued pulls for the migrated tasks. A
//! prefetch miss just falls back to the demand pull; the memory
//! manager's spill writes ride the same transfer threads (asynchronous
//! spill with a write-completion barrier, so a reader can never observe
//! a half-written spill file). Per-node
//! `(prefetch_bytes, prefetch_hits, demand_pull_bytes,
//! async_spill_bytes)` land in `RealReport::prefetch_stats`, and
//! `prefetch_bytes + demand_pull_bytes` accounts every cross-node byte
//! of the run exactly once.
//!
//! The loop closes in the other direction too
//! ([`exec::RuntimeFeedback`], `SessionConfig::feedback`, default on):
//! after every real run the executor reconciles the plan against what
//! actually happened — steal migrations and their bytes, demand-pull
//! misses, spill pressure, NIC traffic the plan never committed, and
//! the replica copies stolen work left behind — and the session folds
//! that into the scheduler's [`scheduler::ClusterState`]
//! ([`scheduler::ClusterState::absorb_feedback`]). The next plan's
//! Eq. 2 simulation therefore starts from where load really landed,
//! and runtime replicas widen its placement options.
//!
//! ## Memory model
//!
//! The real executor owns a cluster [`store::MemoryManager`]. Before a
//! run, [`exec::Lifetimes`] computes per-object consumer refcounts over
//! the plan and pins the graph's outputs; task completion decrements the
//! counts and dead intermediates are evicted from every node immediately,
//! so per-node `peak_bytes` reflects the schedule's working set (the
//! §8.1 "memory load") rather than total allocation
//! (`SessionConfig::lifetime_gc`, default on). Under a per-node byte
//! budget (`SessionConfig::mem_budget_bytes`) the manager sheds load by
//! evicting replica copies first (cross-node pulls register the
//! destination copy as a replica), then spilling the coldest unpinned
//! blocks to per-node temp files and transparently reading them back on
//! access — the real-execution counterpart of the sim executor's spill
//! model, with per-run `(spilled, readback, evicted-replica, gc-freed)`
//! bytes in `RealReport::mem_stats`.
//!
//! Kernel thread budgets are explicit: every
//! `Backend::execute` call takes a [`runtime::ExecContext`], so there is
//! no process-global parallelism state and concurrent sessions cannot
//! clobber each other. `NUMS_MATMUL_THREADS=N` overrides the budget of
//! any context at construction time (`1` = serial kernels, useful on
//! shared CI runners); `NUMS_DEADLOCK_TIMEOUT_SECS` sets how often idle
//! workers re-check for a provable deadlock (nothing running, nothing
//! queued, work left), which fails the run naming the blocking object
//! ids — running kernels are never interrupted, however slow.
//!
//! See the repository's `README.md` for the quick-start, bench and
//! toggle reference, and `docs/ARCHITECTURE.md` for the paper-section →
//! module map and the plan → execute → GC dataflow walkthrough.

pub mod api;
pub mod bench;
pub mod exec;
pub mod glm;
pub mod graph;
pub mod grid;
pub mod io;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scheduler;
pub mod store;
pub mod summa;
pub mod tensor;
pub mod util;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::api::{ExecMode, Policy, Session, SessionConfig};
    pub use crate::graph::{build, DistArray, Graph};
    pub use crate::grid::{ArrayGrid, NodeGrid};
    pub use crate::net::model::{ComputeParams, NetParams, SystemMode};
    pub use crate::net::TransportKind;
    pub use crate::runtime::{Backend, BinOp, EwStep, ExecContext, Kernel, KernelTier};
    pub use crate::scheduler::{ClusterState, Lshs, Topology};
    pub use crate::store::Block;
    pub use crate::util::rng::Rng;
}
