//! `Session` — the NumS driver process (§3).
//!
//! A session owns the simulated cluster (topology + load state + object
//! stores), a scheduling policy, and a kernel backend. Creation ops
//! execute immediately with the policy's data layout (§4); expression
//! graphs are scheduled by the policy and executed either for real
//! (threaded, PJRT/native kernels, actual bytes) or in modeled time
//! (discrete-event, phantom blocks) — or both.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::exec::{Plan, RealExecutor, RealReport, SimExecutor, SimReport};
use crate::graph::{DistArray, Graph};
use crate::metrics::runtime_trace::{chrome_trace_json, EventKind, RtEvent, RunTrace};
use crate::grid::{softmax_grid, ArrayGrid, NodeGrid};
use crate::net::model::{ComputeParams, NetParams, SystemMode};
use crate::net::{InProcessTransport, ShmTransport, TcpTransport, TransportKind};
use crate::runtime::{Backend, KernelTier};
use crate::scheduler::baselines::{BottomUp, RandomPlace, RoundRobin};
use crate::scheduler::{ClusterState, Lshs, PlanCache, Scheduler, Topology};
use crate::store::{Block, IdGen, MemoryManager, ObjectId, StoreSet};
use crate::util::rng::Rng;

/// Scheduling policy selector (the ablation axis of Fig. 9/15).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    Lshs,
    RoundRobin,
    BottomUp,
    Random,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lshs" => Policy::Lshs,
            "round-robin" | "rr" => Policy::RoundRobin,
            "bottom-up" | "ray-default" => Policy::BottomUp,
            "random" => Policy::Random,
            other => return Err(anyhow!("unknown policy {other:?}")),
        })
    }
}

/// Execution mode: real blocks + kernels, or modeled time only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Real,
    Sim,
}

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub nodes: usize,
    pub workers_per_node: usize,
    pub node_grid: Option<NodeGrid>,
    pub mode: SystemMode,
    pub exec: ExecMode,
    pub policy: Policy,
    pub net: NetParams,
    pub compute: ComputeParams,
    pub seed: u64,
    /// Record Fig. 15 trace events in sim reports.
    pub record_trace: bool,
    /// Collapse element-wise chains into single `FusedEw` tasks before
    /// scheduling (`graph::fuse`). On by default; toggleable for the
    /// fusion ablation in `benches/fig09_micro.rs` and for baselines that
    /// model systems without a fusion pass (`glm::driver_agg`).
    pub fusion: bool,
    /// Let the real executor's idle workers steal ready tasks from other
    /// nodes (dependency-counted work stealing; inputs of stolen tasks
    /// are pulled cross-node, paying real bytes). On by default; off
    /// reproduces strict node-affinity FIFO execution for the stealing
    /// ablation in `benches/fig09_micro.rs`. Per-node steal counters land
    /// in `RealReport::node_stats`.
    pub stealing: bool,
    /// Pin the real executor's kernels to the portable scalar tier
    /// (`runtime::KernelTier::Scalar`), which is bit-for-bit identical to
    /// the `matmul_naive` oracle and across thread counts. On by default
    /// so every exact-equality property contract holds; benches flip it
    /// off (`with_strict_kernels(false)`) to dispatch the packed
    /// AVX2+FMA microkernels, whose results differ from scalar only
    /// within the documented epsilon bound (`tests/kernel_tier.rs`). The
    /// `NUMS_KERNEL_TIER=scalar` env override still wins either way.
    pub strict_kernels: bool,
    /// Overlap communication with compute during real execution: one
    /// transfer thread per node prefetches the remote inputs of
    /// near-ready tasks (guided by the scheduler's committed transfer
    /// decisions in the plan) and absorbs the memory manager's spill
    /// writes, so workers rarely pay transfer or spill latency on the
    /// hot path. On by default; off is the ablation baseline where every
    /// byte moves synchronously (demand pulls, blocking spill writes).
    /// Per-node `(prefetch_bytes, prefetch_hits, demand_pull_bytes,
    /// async_spill_bytes)` land in `RealReport::prefetch_stats`.
    pub prefetch: bool,
    /// Release dead intermediates eagerly during real execution: a
    /// pre-run lifetime pass over the plan counts per-object consumers,
    /// and the executor evicts an unpinned intermediate from every node
    /// the moment its last consumer finishes (the real-execution
    /// counterpart of Ray/Dask refcount GC, already modeled in
    /// `exec::sim_exec`). On by default; off is the memory ablation
    /// baseline where `peak_bytes` equals total allocation.
    pub lifetime_gc: bool,
    /// Per-node resident-byte budget for real execution. When a `put`
    /// would exceed it, the memory manager first evicts replica copies
    /// (objects whose primary lives on another node), then spills the
    /// coldest unpinned blocks to per-node temp files, reading them back
    /// transparently on access. `None` (default) = unlimited. Per-node
    /// `(spilled, readback, evicted-replica)` bytes land in
    /// `RealReport::mem_stats`. The prefetcher's queued-pull lookahead is
    /// bounded to half this budget, so overlap never pulls what pressure
    /// would immediately evict.
    pub mem_budget_bytes: Option<u64>,
    /// Close the plan↔runtime loop: fold each real run's observed
    /// [`crate::exec::RuntimeFeedback`] — steal migrations, demand-pull
    /// misses, spill pressure, unplanned NIC traffic, runtime replica
    /// copies — into the scheduler's [`ClusterState`] before the next
    /// `run()`, so the next plan's Eq. 2 simulation starts from where
    /// load actually landed. On by default; off is the ablation baseline
    /// (the planner only ever sees its own committed decisions) measured
    /// by the fig09 feedback ablation.
    pub feedback: bool,
    /// Memoize plans across `run()` calls, keyed by the canonical graph
    /// signature ([`crate::graph::signature`]). Iterative drivers submit
    /// the same topology every iteration; on a hit the cached plan is
    /// *rebound* onto this run's input objects and fresh output ids
    /// ([`crate::scheduler::plan_cache`]) instead of re-running the LSHS
    /// local search — `RunReport::simulations` is 0 on the hit path.
    /// Results stay bit-identical (reduce pairings are frozen in the
    /// plan); staleness from absorbed feedback triggers a synchronous
    /// foreground re-plan. On by default; off re-plans every run (the
    /// fig09 `plan_cache` ablation baseline).
    pub plan_cache: bool,
    /// Trace real runs: per-task spans (queue-wait, input-fetch, kernel
    /// execution) and runtime events (fetches tagged prefetch/demand,
    /// spills, read-backs, evictions, GC frees, steals, plan-cache
    /// hits), folded post-run into per-node Fig. 15 series, a Chrome
    /// trace-event JSON, and a plan-vs-actual divergence report
    /// ([`crate::metrics::runtime_trace`], via `RunReport::trace()`).
    /// Off by default: no recorder exists, results are bit-identical to
    /// an untraced run. Setting `NUMS_TRACE=<path>` turns tracing on and
    /// additionally writes the Chrome JSON of each run to `<path>`
    /// (last run wins).
    pub tracing: bool,
    /// Deterministic fault injection for real runs
    /// ([`crate::exec::FaultPlan`]): seeded failures at the kernel,
    /// transfer, and spill I/O sites, plus at most one scheduled
    /// whole-node loss. Transient faults retry with bounded backoff;
    /// lost objects are recomputed from plan lineage — a chaos run must
    /// produce bit-identical results to a fault-free one (scalar tier),
    /// with the recovery work reported in
    /// `RealReport::recovery_stats`. `None` (default) arms nothing and
    /// costs nothing; the `NUMS_FAULT_SEED` / `NUMS_FAULT_RATE`
    /// environment variables arm rate-based injection (never node loss)
    /// when this field is unset.
    pub fault_plan: Option<crate::exec::FaultPlan>,
    /// Physical block carrier under `StoreSet::try_transfer` (real mode
    /// only; simulated execution moves no real bytes). `InProcess`
    /// (default) Arc-clones between stores — today's behavior and the
    /// sequential oracle. `SharedMem` round-trips every transfer through
    /// a checksummed `/dev/shm`-backed file; `Tcp` launches one OS
    /// process per node (the `nums node` subcommand, binary from
    /// `NUMS_NODE_BIN` or the current executable) and moves framed
    /// blocks over loopback sockets with heartbeats. Results must be
    /// bit-identical across all three (scalar tier) and the per-node
    /// `prefetch + demand == net_in` identity holds on each — that is
    /// what `tests/transport.rs` enforces. Constructors default from the
    /// `NUMS_TRANSPORT` env var (`inproc`|`shm`|`tcp`), so the whole
    /// suite can be re-run on a real transport without code changes.
    pub transport: TransportKind,
}

impl SessionConfig {
    /// Small real-execution cluster (tests, examples).
    pub fn real_small(nodes: usize, workers_per_node: usize) -> Self {
        Self {
            nodes,
            workers_per_node,
            node_grid: None,
            mode: SystemMode::Ray,
            exec: ExecMode::Real,
            policy: Policy::Lshs,
            net: NetParams::localhost(),
            compute: ComputeParams::paper_testbed(),
            seed: 0xC0FFEE,
            record_trace: false,
            fusion: true,
            stealing: true,
            strict_kernels: true,
            prefetch: true,
            lifetime_gc: true,
            mem_budget_bytes: None,
            feedback: true,
            plan_cache: true,
            tracing: false,
            fault_plan: None,
            transport: TransportKind::from_env(),
        }
    }

    /// The paper's 16-node × 32-worker testbed, simulated (§8).
    pub fn paper_sim(nodes: usize, workers_per_node: usize) -> Self {
        Self {
            nodes,
            workers_per_node,
            node_grid: None,
            mode: SystemMode::Ray,
            exec: ExecMode::Sim,
            policy: Policy::Lshs,
            net: NetParams::paper_testbed(),
            compute: ComputeParams::paper_testbed(),
            seed: 0xC0FFEE,
            record_trace: false,
            fusion: true,
            stealing: true,
            strict_kernels: true,
            prefetch: true,
            lifetime_gc: true,
            mem_budget_bytes: None,
            feedback: true,
            plan_cache: true,
            tracing: false,
            fault_plan: None,
            transport: TransportKind::from_env(),
        }
    }

    pub fn with_policy(mut self, p: Policy) -> Self {
        self.policy = p;
        self
    }

    pub fn with_fusion(mut self, on: bool) -> Self {
        self.fusion = on;
        self
    }

    /// Toggle strict (scalar, bit-reproducible) kernels
    /// (see [`SessionConfig::strict_kernels`]).
    pub fn with_strict_kernels(mut self, on: bool) -> Self {
        self.strict_kernels = on;
        self
    }

    /// Toggle real-executor work stealing (see [`SessionConfig::stealing`]).
    pub fn with_stealing(mut self, on: bool) -> Self {
        self.stealing = on;
        self
    }

    /// Toggle communication/compute overlap
    /// (see [`SessionConfig::prefetch`]).
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Toggle plan-lifetime GC (see [`SessionConfig::lifetime_gc`]).
    pub fn with_lifetime_gc(mut self, on: bool) -> Self {
        self.lifetime_gc = on;
        self
    }

    /// Set the per-node resident-byte budget
    /// (see [`SessionConfig::mem_budget_bytes`]).
    pub fn with_mem_budget(mut self, bytes: u64) -> Self {
        self.mem_budget_bytes = Some(bytes);
        self
    }

    /// Toggle the plan↔runtime feedback loop
    /// (see [`SessionConfig::feedback`]).
    pub fn with_feedback(mut self, on: bool) -> Self {
        self.feedback = on;
        self
    }

    /// Toggle the plan cache (see [`SessionConfig::plan_cache`]).
    pub fn with_plan_cache(mut self, on: bool) -> Self {
        self.plan_cache = on;
        self
    }

    /// Toggle real-run tracing (see [`SessionConfig::tracing`]).
    pub fn with_tracing(mut self, on: bool) -> Self {
        self.tracing = on;
        self
    }

    /// Arm deterministic fault injection
    /// (see [`SessionConfig::fault_plan`]).
    pub fn with_fault_plan(mut self, plan: crate::exec::FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Select the block carrier (see [`SessionConfig::transport`]).
    pub fn with_transport(mut self, t: TransportKind) -> Self {
        self.transport = t;
        self
    }

    pub fn with_mode(mut self, m: SystemMode) -> Self {
        self.mode = m;
        self
    }

    pub fn with_node_grid(mut self, g: NodeGrid) -> Self {
        self.node_grid = Some(g);
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Outcome of one `run()` (one scheduled expression graph).
#[derive(Debug, Default)]
pub struct RunReport {
    pub tasks: usize,
    pub transfers: usize,
    pub transfer_bytes: u64,
    pub sim: SimReport,
    pub real: Option<RealReport>,
    /// Scheduling wall time (the γ-side cost LSHS itself adds): fusion +
    /// signature + search-or-rebind. `search_secs` isolates the part the
    /// plan cache amortizes.
    pub schedule_secs: f64,
    /// Wall time of the local search (miss) or of signature + rebind
    /// (hit) — the `schedule_secs` split the fig09 planning arm reports.
    pub search_secs: f64,
    /// Element-wise ops absorbed by the fusion pass (tasks saved).
    pub fused_ops: usize,
    /// Whether this run replayed a cached plan instead of scheduling.
    pub plan_cache_hit: bool,
    /// Session-cumulative plan-cache hits (0 when the cache is off).
    pub plan_cache_hits: u64,
    /// Session-cumulative plan-cache misses, including stale re-plans.
    pub plan_cache_misses: u64,
    /// Placement decisions this run (`Lshs::decisions` delta; 0 on a hit
    /// and for non-simulating baselines).
    pub decisions: u64,
    /// Candidate placement simulations this run (`Lshs::simulations`
    /// delta; 0 on a hit — the whole point of the cache).
    pub simulations: u64,
}

impl RunReport {
    /// The real run's trace (spans, events, per-node series, divergence
    /// report) when the session ran with `SessionConfig::tracing` on.
    pub fn trace(&self) -> Option<&RunTrace> {
        self.real.as_ref().and_then(|r| r.trace.as_ref())
    }
}

pub struct Session {
    pub cfg: SessionConfig,
    pub topo: Topology,
    scheduler: Box<dyn Scheduler + Send>,
    pub state: ClusterState,
    ids: IdGen,
    pub stores: StoreSet,
    pub backend: Arc<Backend>,
    /// Built once at session construction (real mode only): worker pools
    /// and stealing mode are session-lifetime state, not per-`run()`.
    real_exec: Option<RealExecutor>,
    data_rng: Rng,
    /// Every materialized object: (target, bytes) — seeds sim-exec runs.
    objects: Vec<(ObjectId, usize, u64)>,
    /// Plan memo keyed by canonical graph signature
    /// (see [`SessionConfig::plan_cache`]).
    plan_cache: PlanCache,
    /// The plan of the most recent `run()` (fresh or rebound) — kept for
    /// introspection: the plan-cache property suite replays it through
    /// the sequential oracle and audits rebound input liveness.
    pub last_plan: Option<Plan>,
    /// Cumulative reports.
    pub total_tasks: usize,
    pub total_transfer_bytes: u64,
    pub total_sim_makespan: f64,
}

impl Session {
    pub fn new(cfg: SessionConfig) -> Self {
        Self::with_backend(cfg, Arc::new(Backend::auto()))
    }

    pub fn with_backend(cfg: SessionConfig, backend: Arc<Backend>) -> Self {
        let topo = Topology::new(cfg.nodes, cfg.workers_per_node, cfg.mode);
        let node_grid = cfg
            .node_grid
            .clone()
            .unwrap_or_else(|| NodeGrid::linear(cfg.nodes));
        let scheduler: Box<dyn Scheduler + Send> = match cfg.policy {
            Policy::Lshs => Box::new(Lshs::new(node_grid, topo.clone(), cfg.seed)),
            Policy::RoundRobin => Box::new(RoundRobin::new()),
            Policy::BottomUp => Box::new(BottomUp::new()),
            Policy::Random => Box::new(RandomPlace::new(cfg.seed)),
        };
        let real_exec = if cfg.exec == ExecMode::Real {
            let memory =
                MemoryManager::new(topo.nodes, cfg.mem_budget_bytes, cfg.lifetime_gc);
            let tier = if cfg.strict_kernels {
                KernelTier::Scalar
            } else {
                KernelTier::detect()
            };
            // NUMS_TRACE=<path> implies tracing on (and exports the
            // Chrome JSON after each run)
            let tracing = cfg.tracing
                || std::env::var("NUMS_TRACE").map_or(false, |v| !v.is_empty());
            // explicit session plan wins; otherwise the env vars may arm
            // rate-based chaos (never a node loss) for the whole session
            let fault_plan = cfg
                .fault_plan
                .clone()
                .or_else(crate::exec::FaultPlan::from_env);
            Some(
                RealExecutor::new(topo.clone(), Arc::clone(&backend))
                    .with_stealing(cfg.stealing)
                    .with_prefetch(cfg.prefetch)
                    .with_tier(tier)
                    .with_memory(memory)
                    .with_tracing(tracing)
                    .with_faults(fault_plan),
            )
        } else {
            None
        };
        // simulated execution moves no real bytes, so it always gets the
        // plain in-process store set regardless of the configured carrier
        let stores = match (cfg.exec, cfg.transport) {
            (ExecMode::Real, TransportKind::SharedMem) => StoreSet::with_transport(
                topo.nodes,
                Arc::new(
                    ShmTransport::new()
                        .expect("shm transport: cannot create block hand-off directory"),
                ),
            ),
            (ExecMode::Real, TransportKind::Tcp) => {
                // the node-daemon binary: NUMS_NODE_BIN when set (tests
                // point it at the built `nums` binary), else this very
                // executable (the nums CLI launching its own peers)
                let bin = std::env::var("NUMS_NODE_BIN")
                    .map(std::path::PathBuf::from)
                    .or_else(|_| std::env::current_exe())
                    .expect("tcp transport: no node binary (set NUMS_NODE_BIN)");
                let t = TcpTransport::launch(topo.nodes, &bin).unwrap_or_else(|e| {
                    panic!(
                        "tcp transport: failed to launch {} node processes from \
                         {bin:?}: {e} (set NUMS_NODE_BIN to the nums binary)",
                        topo.nodes
                    )
                });
                StoreSet::with_transport(topo.nodes, Arc::new(t))
            }
            (ExecMode::Real, TransportKind::InProcess)
                if std::env::var("NUMS_TRANSPORT_METRICS").map_or(false, |v| v == "1") =>
            {
                // per-transfer timing for the net bench's baseline arm
                StoreSet::with_transport(topo.nodes, Arc::new(InProcessTransport::with_metrics()))
            }
            _ => StoreSet::new(topo.nodes),
        };
        Session {
            topo: topo.clone(),
            state: ClusterState::new(topo.clone()),
            ids: IdGen::default(),
            stores,
            backend,
            real_exec,
            data_rng: Rng::seed_from_u64(cfg.seed ^ 0xDA7A),
            objects: Vec::new(),
            plan_cache: PlanCache::default(),
            last_plan: None,
            total_tasks: 0,
            total_transfer_bytes: 0,
            total_sim_makespan: 0.0,
            scheduler,
            cfg,
        }
    }

    pub fn policy_name(&self) -> String {
        self.scheduler.name()
    }

    /// The cluster memory manager (real mode), owned by the executor.
    pub fn memory(&self) -> Option<&MemoryManager> {
        self.real_exec.as_ref().and_then(|e| e.memory.as_ref())
    }

    /// Place a creation-time block on `node`, through the memory manager
    /// when one exists (so creation data obeys the byte budget too).
    fn place_block(&self, node: usize, obj: ObjectId, block: Arc<Block>) {
        match self.memory() {
            Some(m) => m.insert(&self.stores, node, obj, block, &|_| true),
            None => self.stores.put(node, obj, block),
        }
    }

    /// Locate a block anywhere — resident in a store, or (with a
    /// manager) paged out to a spill file.
    fn fetch_block(&self, obj: ObjectId) -> Option<Arc<Block>> {
        match self.memory() {
            Some(m) => m.fetch(&self.stores, obj),
            None => self.stores.fetch(obj),
        }
    }

    // ------------------------------------------------------------ creation

    /// Automatic partitioning `p^{σ(shape)}` (§4).
    pub fn auto_grid(&self, shape: &[usize]) -> Vec<usize> {
        softmax_grid(shape, self.topo.total_workers())
    }

    /// Create an array from a per-block generator function.
    pub fn create_with(
        &mut self,
        shape: &[usize],
        grid: &[usize],
        gen: impl FnMut(&mut Rng, &[usize], &[usize]) -> Vec<f64>,
    ) -> DistArray {
        let g = ArrayGrid::new(shape, grid);
        let targets = self.scheduler.place_creation(&g, &mut self.state);
        self.create_placed(g, targets, gen)
    }

    /// Shared creation body: register every block of `g` at its target in
    /// the load model, materialize data (real mode) with the per-block
    /// deterministic seeding, and assemble the [`DistArray`]. Placement
    /// comes from the caller — the policy's layout ([`Session::create_with`])
    /// or a deliberate skew ([`Session::create_at`]).
    fn create_placed(
        &mut self,
        g: ArrayGrid,
        targets: Vec<usize>,
        mut gen: impl FnMut(&mut Rng, &[usize], &[usize]) -> Vec<f64>,
    ) -> DistArray {
        let mut blocks = Vec::with_capacity(g.num_blocks());
        for (f, coords) in g.iter_coords().enumerate() {
            let obj = self.ids.next();
            let bshape = g.block_shape(&coords);
            let elems = g.block_elems(&coords);
            self.state.register(obj, elems as f64, targets[f]);
            self.objects.push((obj, targets[f], elems * 8));
            if self.cfg.exec == ExecMode::Real {
                let mut rng = Rng::seed_from_u64(self.cfg.seed ^ obj.wrapping_mul(0x9E3779B97F4A7C15));
                let data = gen(&mut rng, &bshape, &coords);
                assert_eq!(data.len() as u64, elems);
                self.place_block(
                    self.topo.node_of(targets[f]),
                    obj,
                    Arc::new(Block::from_vec(&bshape, data)),
                );
            }
            blocks.push(obj);
        }
        let _ = &mut self.data_rng;
        DistArray::new(g, blocks, targets)
    }

    /// [`Session::create_with`], but with every block deliberately placed
    /// at one `target` instead of the policy's layout — the canonical way
    /// to build *skewed* layouts for scheduling experiments (the fig09
    /// stealing/feedback ablations and the feedback test suite). The load
    /// model registers the blocks where they really are, so the first
    /// plan over them sees exactly the skew the experiment intends.
    pub fn create_at(
        &mut self,
        shape: &[usize],
        grid: &[usize],
        target: usize,
        gen: impl FnMut(&mut Rng, &[usize], &[usize]) -> Vec<f64>,
    ) -> DistArray {
        assert!(target < self.topo.targets(), "target out of range");
        let g = ArrayGrid::new(shape, grid);
        let targets = vec![target; g.num_blocks()];
        self.create_placed(g, targets, gen)
    }

    /// Skewed [`Session::randn`]: every block on one target
    /// (see [`Session::create_at`]).
    pub fn randn_at(&mut self, shape: &[usize], grid: &[usize], target: usize) -> DistArray {
        self.create_at(shape, grid, target, |rng, bs, _| {
            let mut v = vec![0.0; bs.iter().product::<usize>()];
            rng.fill_normal(&mut v);
            v
        })
    }

    pub fn zeros(&mut self, shape: &[usize], grid: &[usize]) -> DistArray {
        self.create_with(shape, grid, |_, bs, _| {
            vec![0.0; bs.iter().product::<usize>()]
        })
    }

    pub fn full(&mut self, shape: &[usize], grid: &[usize], v: f64) -> DistArray {
        self.create_with(shape, grid, move |_, bs, _| {
            vec![v; bs.iter().product::<usize>()]
        })
    }

    pub fn ones(&mut self, shape: &[usize], grid: &[usize]) -> DistArray {
        self.full(shape, grid, 1.0)
    }

    /// Standard-normal random array (per-block deterministic seeding).
    pub fn randn(&mut self, shape: &[usize], grid: &[usize]) -> DistArray {
        self.create_with(shape, grid, |rng, bs, _| {
            let mut v = vec![0.0; bs.iter().product::<usize>()];
            rng.fill_normal(&mut v);
            v
        })
    }

    /// Scatter a dense host matrix into a distributed array (2-D).
    pub fn scatter2(&mut self, data: &Block, grid: &[usize]) -> DistArray {
        assert_eq!(data.ndim(), 2);
        let shape = data.shape.clone();
        let g = ArrayGrid::new(&shape, grid);
        let src = data.clone();
        self.create_with(&shape, grid, move |_, bs, coords| {
            let r0 = g.block_offset(0, coords[0]);
            let c0 = g.block_offset(1, coords[1]);
            let mut out = Vec::with_capacity(bs[0] * bs[1]);
            for i in 0..bs[0] {
                for j in 0..bs[1] {
                    out.push(src.at2(r0 + i, c0 + j));
                }
            }
            out
        })
    }

    // ----------------------------------------------------------- execution

    /// Schedule and execute an expression graph; returns one materialized
    /// [`DistArray`] per graph output plus the run report.
    pub fn run(&mut self, graph: &mut Graph) -> Result<(Vec<DistArray>, RunReport)> {
        let sw = crate::util::Stopwatch::start();
        // planning step 1: fold Scale/Neg epilogues into their contraction
        // (α applied during C-writeback), then collapse the remaining
        // element-wise chains (one task, one placement decision, zero
        // intermediates per chain)
        let fuse_stats = if self.cfg.fusion {
            let folded = crate::graph::fuse::fuse_epilogues(graph);
            let mut st = crate::graph::fuse::fuse_elementwise(graph);
            st.absorbed += folded;
            st
        } else {
            crate::graph::fuse::FuseStats::default()
        };
        // planning step 2: search or replay. With the plan cache on, the
        // post-fusion graph is condensed into a canonical signature; a
        // fresh cached plan for it is rebound (symbolic slots -> this
        // run's inputs + fresh ids, placements/transfers replayed into
        // the load model) instead of re-running the local search. A miss
        // — cold, capacity-evicted, or stale from absorbed feedback —
        // schedules as always and captures the result.
        let search_sw = crate::util::Stopwatch::start();
        let (d0, s0) = self.scheduler.search_stats();
        let mut plan = Plan::new();
        let mut plan_cache_hit = false;
        if self.cfg.plan_cache {
            let (sig, inputs) = crate::graph::signature(graph, &self.state);
            if self.plan_cache.lookup(sig) {
                let entry = self.plan_cache.get(sig).expect("fresh entry after lookup");
                entry.rebind(&inputs, &self.ids, graph, &mut self.state, &mut plan);
                plan_cache_hit = true;
            } else {
                self.scheduler
                    .schedule(graph, &mut self.state, &self.ids, &mut plan);
                if let Some(entry) = PlanCache::capture(&inputs, graph, &plan) {
                    self.plan_cache.insert(sig, entry);
                }
            }
        } else {
            self.scheduler
                .schedule(graph, &mut self.state, &self.ids, &mut plan);
        }
        let search_secs = search_sw.secs();
        let (d1, s1) = self.scheduler.search_stats();
        let schedule_secs = sw.secs();

        // modeled execution (always: it is cheap and feeds the figures)
        let mut sim_exec = SimExecutor::new(self.topo.clone(), self.cfg.net, self.cfg.compute);
        sim_exec.record_trace = self.cfg.record_trace;
        let sim = sim_exec.run(&plan, &self.objects);

        // real execution on the session-lifetime executor; the graph's
        // output blocks are pinned so lifetime GC and budget spilling
        // never touch what the driver is about to hand back
        let mut real = match &self.real_exec {
            Some(exec) => {
                let pins: Vec<ObjectId> = graph
                    .outputs
                    .iter()
                    .flat_map(|o| o.roots.iter().map(|&r| graph.resolve(r)))
                    .collect();
                Some(exec.run_pinned(&plan, &self.stores, &pins)?)
            }
            None => None,
        };

        // stamp the planning outcome into the trace (t=0 sorts first),
        // and honor the NUMS_TRACE export path
        if let Some(tr) = real.as_mut().and_then(|r| r.trace.as_mut()) {
            if plan_cache_hit {
                tr.events.insert(
                    0,
                    RtEvent {
                        t: 0.0,
                        node: 0,
                        src: None,
                        obj: None,
                        bytes: 0,
                        kind: EventKind::PlanCacheHit,
                    },
                );
            }
            if let Ok(path) = std::env::var("NUMS_TRACE") {
                if !path.is_empty() {
                    // best-effort export (a bad path must not fail the run);
                    // successive runs overwrite — last run wins
                    let _ = std::fs::write(&path, chrome_trace_json(tr));
                }
            }
        }

        // close the plan↔runtime loop: fold what the executor observed
        // but the plan never committed (steal migrations, demand pulls,
        // spill pressure, runtime replicas) into the load model, so the
        // next schedule() simulates against where load actually landed.
        // Absorbed *before* the forget pass below — replicas of dead
        // intermediates must be unwound again, not survive it.
        if self.cfg.feedback {
            if let Some(r) = &real {
                self.state.absorb_feedback(&r.feedback);
                // absorbed drift ages every cached plan: entries planned
                // against the pre-drift model re-plan (in the foreground)
                // once the accumulated magnitude crosses the threshold
                self.plan_cache.note_feedback(r.feedback.pressure_elems());
            }
        }

        // lifetime GC freed dead intermediates during the run: make the
        // scheduler's load model forget them too, so the next schedule()
        // on this session does not count dead bytes in the Eq. 2 memory
        // term (and they never enter the sim-seed object list below)
        let dead: std::collections::HashSet<ObjectId> = match &real {
            Some(r) => {
                for &obj in &r.gc_released {
                    self.state.forget(obj);
                }
                r.gc_released.iter().copied().collect()
            }
            None => Default::default(),
        };

        // a node loss wiped real copies the load model still counts:
        // drop exactly that node's copies, and re-register any object
        // lineage recovery re-materialized elsewhere so later plans can
        // source it from its actual home
        if let Some(r) = &real {
            for (node, lost) in &r.node_losses {
                for &(obj, bytes) in lost {
                    self.state.forget_copies_on(obj, *node);
                    if self.state.locations_of(obj).is_empty() {
                        if let Some(n) =
                            (0..self.topo.nodes).find(|&n| self.stores.contains(n, obj))
                        {
                            self.state.register(obj, (bytes / 8) as f64, n);
                        }
                    }
                }
            }
        }

        // register surviving outputs as resident objects for later runs
        for (obj, shape, target) in plan.produced() {
            if dead.contains(&obj) {
                continue;
            }
            let bytes: u64 = shape.iter().map(|&d| d as u64).product::<u64>() * 8;
            self.objects.push((obj, target, bytes));
        }

        // materialize outputs
        let outs: Vec<DistArray> = graph
            .outputs
            .iter()
            .map(|o| {
                let blocks: Vec<ObjectId> =
                    o.roots.iter().map(|&r| graph.resolve(r)).collect();
                let targets: Vec<usize> = blocks
                    .iter()
                    .map(|&b| {
                        self.state
                            .locations_of(b)
                            .first()
                            .copied()
                            .unwrap_or(0)
                    })
                    .collect();
                DistArray::new(o.grid.clone(), blocks, targets)
            })
            .collect();

        self.total_tasks += plan.len();
        self.total_transfer_bytes += plan.transfer_bytes();
        self.total_sim_makespan += sim.makespan;

        let report = RunReport {
            tasks: plan.len(),
            transfers: plan.transfer_count(),
            transfer_bytes: plan.transfer_bytes(),
            sim,
            real,
            schedule_secs,
            search_secs,
            fused_ops: fuse_stats.absorbed,
            plan_cache_hit,
            plan_cache_hits: self.plan_cache.hits,
            plan_cache_misses: self.plan_cache.misses,
            decisions: d1 - d0,
            simulations: s1 - s0,
        };
        self.last_plan = Some(plan);
        Ok((outs, report))
    }

    /// Session-cumulative plan-cache counters:
    /// `(hits, misses, stale re-plans)`.
    pub fn plan_cache_stats(&self) -> (u64, u64, u64) {
        (
            self.plan_cache.hits,
            self.plan_cache.misses,
            self.plan_cache.stale_replans,
        )
    }

    /// Gather a distributed array into a dense host block (real mode).
    pub fn fetch(&self, a: &DistArray) -> Result<Block> {
        if self.cfg.exec != ExecMode::Real {
            return Err(anyhow!("fetch() requires ExecMode::Real"));
        }
        let shape = &a.grid.shape;
        let n: usize = shape.iter().product();
        let mut out = vec![0.0; n];
        // generic n-d assembly via per-axis offsets
        for coords in a.grid.iter_coords() {
            let obj = a.obj_at(&coords);
            let block = self
                .fetch_block(obj)
                .ok_or_else(|| anyhow!("block {obj} not found in any store"))?;
            let bshape = &block.shape;
            let offsets: Vec<usize> = (0..shape.len())
                .map(|ax| a.grid.block_offset(ax, coords[ax]))
                .collect();
            // iterate block elements row-major
            let belems: usize = bshape.iter().product();
            let mut idx = vec![0usize; bshape.len()];
            for flat in 0..belems {
                // global flat index
                let mut gflat = 0usize;
                for ax in 0..shape.len() {
                    gflat = gflat * shape[ax] + (offsets[ax] + idx[ax]);
                }
                out[gflat] = block.buf()[flat];
                // increment odometer
                for ax in (0..bshape.len()).rev() {
                    idx[ax] += 1;
                    if idx[ax] < bshape[ax] {
                        break;
                    }
                    idx[ax] = 0;
                }
            }
        }
        Ok(Block::from_vec(shape, out))
    }

    /// Fetch a single scalar (1x1 arrays: losses, norms).
    pub fn fetch_scalar(&self, a: &DistArray) -> Result<f64> {
        let b = self.fetch(a)?;
        if b.elems() != 1 {
            return Err(anyhow!("fetch_scalar on array with {} elems", b.elems()));
        }
        Ok(b.buf()[0])
    }

    /// Seed the session with an externally-built block (tests, CSV
    /// reader): the block becomes a single-block [`DistArray`] of its own
    /// shape, resident on `target`.
    pub fn adopt_block(&mut self, block: Block, target: usize) -> DistArray {
        let obj = self.ids.next();
        self.state
            .register(obj, block.elems() as f64, target);
        self.objects.push((obj, target, block.bytes()));
        let shape = block.shape.clone();
        if self.cfg.exec == ExecMode::Real {
            self.place_block(self.topo.node_of(target), obj, Arc::new(block));
        }
        let grid = ArrayGrid::new(&shape, &vec![1; shape.len()]);
        DistArray::new(grid, vec![obj], vec![target])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ops;

    #[test]
    fn adopt_block_returns_a_correctly_shaped_array() {
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let block = Block::from_vec(&[3, 4], (0..12).map(|v| v as f64).collect());
        let arr = sess.adopt_block(block.clone(), 1);
        assert_eq!(arr.shape(), vec![3, 4]);
        assert_eq!(arr.num_blocks(), 1);
        assert_eq!(arr.targets, vec![1]);
        let back = sess.fetch(&arr).unwrap();
        assert_eq!(back.shape, block.shape);
        assert_eq!(back.max_abs_diff(&block), 0.0);
    }

    #[test]
    fn sessions_with_different_topologies_do_not_share_state() {
        // regression for the old global parallelism hint: two live
        // sessions must keep independent executors and produce correct
        // results regardless of construction order
        let mut a = Session::new(SessionConfig::real_small(1, 1));
        let mut b = Session::new(SessionConfig::real_small(4, 2).with_stealing(false));
        for sess in [&mut a, &mut b] {
            let x = sess.randn(&[64, 8], &[4, 1]);
            let y = sess.ones(&[64, 8], &[4, 1]);
            let (out, _) = ops::add(sess, &x, &y).unwrap();
            let got = sess.fetch(&out).unwrap();
            let want_x = sess.fetch(&x).unwrap();
            for (g, w) in got.buf().iter().zip(want_x.buf()) {
                assert_eq!(*g, *w + 1.0);
            }
        }
    }
}
