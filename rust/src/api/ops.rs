//! Expression helpers: build a graph for one array expression and run it
//! immediately (§6's "expressions are computed upon assignment").

use anyhow::Result;

use crate::graph::{build, DistArray, Graph};
use crate::runtime::kernel::{BinOp, EwStep, Kernel};

use super::session::{RunReport, Session};

fn run_one(sess: &mut Session, graph: &mut Graph) -> Result<(DistArray, RunReport)> {
    let (mut outs, rep) = sess.run(graph)?;
    Ok((outs.remove(0), rep))
}

/// `-X`
pub fn neg(sess: &mut Session, a: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::unary(&mut g, a, Kernel::Neg);
    run_one(sess, &mut g)
}

/// `sigmoid(X)` (used by GLM tests)
pub fn sigmoid(sess: &mut Session, a: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::unary(&mut g, a, Kernel::Sigmoid);
    run_one(sess, &mut g)
}

/// `X + Y`
pub fn add(sess: &mut Session, a: &DistArray, b: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::binary_ew(&mut g, a, b, BinOp::Add);
    run_one(sess, &mut g)
}

/// `X - Y`
pub fn sub(sess: &mut Session, a: &DistArray, b: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::binary_ew(&mut g, a, b, BinOp::Sub);
    run_one(sess, &mut g)
}

/// `X * Y` (element-wise)
pub fn mul(sess: &mut Session, a: &DistArray, b: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::binary_ew(&mut g, a, b, BinOp::Mul);
    run_one(sess, &mut g)
}

/// An element-wise chain (e.g. `sigmoid(-X · 2 + Y)`) expressed as
/// [`EwStep`]s over `first` plus one operand per binary step. Built
/// unfused; `SessionConfig::fusion` collapses it to one task per block.
pub fn ew_chain(
    sess: &mut Session,
    first: &DistArray,
    rest: &[&DistArray],
    steps: &[EwStep],
) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::ew_chain(&mut g, first, rest, steps);
    run_one(sess, &mut g)
}

/// `X @ Y` with lazy-transpose fusion (accepts `.t()` views).
pub fn matmul(sess: &mut Session, a: &DistArray, b: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::matmul(&mut g, a, b);
    run_one(sess, &mut g)
}

/// `sum(X, axis)`
pub fn sum_axis(sess: &mut Session, a: &DistArray, axis: usize) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::sum_axis(&mut g, a, axis);
    run_one(sess, &mut g)
}

/// `sum(X)` (full reduction to 1×1)
pub fn sum_all(sess: &mut Session, a: &DistArray) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::sum_all(&mut g, a);
    run_one(sess, &mut g)
}

/// `einsum("ijk,jf,kf->if", X, B, C)` — MTTKRP (§8.4).
pub fn mttkrp(
    sess: &mut Session,
    x: &DistArray,
    b: &DistArray,
    c: &DistArray,
) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::mttkrp(&mut g, x, b, c);
    run_one(sess, &mut g)
}

/// `tensordot(X, Y, axes=2)` over (j, k) — double contraction (§8.4).
pub fn tensordot(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
) -> Result<(DistArray, RunReport)> {
    let mut g = Graph::new();
    build::tensordot_jk(&mut g, x, y);
    run_one(sess, &mut g)
}
