//! User-facing NumPy-like API: the [`Session`] driver and expression
//! helpers that build and immediately run graphs ("computed on
//! assignment", §6).

pub mod ops;
pub mod session;

pub use ops::*;
pub use session::{ExecMode, Policy, RunReport, Session, SessionConfig};
