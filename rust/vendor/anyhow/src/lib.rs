//! Minimal offline stand-in for the `anyhow` crate (vendored; the build
//! image has no crates.io access). Implements exactly the subset this
//! workspace uses: [`Error`], [`Result`], `anyhow!`, `bail!`, and
//! [`Context`] for both `Result` and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent with the
//! reflexive `From<T> for T`.

use std::any::Any;
use std::fmt;

/// A dynamic error: the rendered message chain, plus (when the error
/// arrived through the blanket `From<E: std::error::Error>` conversion)
/// the original typed value, recoverable via [`Error::downcast_ref`] —
/// the slice of the real crate's downcasting that callers here need to
/// pull a typed `ExecError` back out of a `?`-converted result.
pub struct Error {
    msg: String,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Construct from anything displayable (the real crate's `Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), payload: None }
    }

    /// The original typed error, if this `Error` was built from one via
    /// the blanket `From` conversion (message-only errors return `None`).
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let msg = e.to_string();
        Error { msg, payload: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("fmt {args}")`, `anyhow!(displayable)` — build an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `bail!(...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to an error (or a missing `Option` value).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let _ = std::fs::File::open("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
        assert!(!format!("{e:?}").is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("got {n} and {}", 4);
        assert_eq!(format!("{e}"), "got 3 and 4");
        let owned = String::from("owned message");
        let e = anyhow!(owned);
        assert_eq!(format!("{e}"), "owned message");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
    }

    #[test]
    fn context_wraps_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");

        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");

        let some: Option<i32> = Some(5);
        assert_eq!(some.context("unused").unwrap(), 5);
    }

    #[test]
    fn downcast_recovers_the_typed_error() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl fmt::Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed {}", self.0)
            }
        }
        impl std::error::Error for Typed {}

        let e: Error = Typed(7).into();
        assert_eq!(format!("{e}"), "typed 7");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());

        // message-only errors carry no payload
        let m = anyhow!("plain");
        assert!(m.downcast_ref::<Typed>().is_none());
    }
}
