"""L2 model-level behaviour: Newton convergence on separable synthetic data."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def _bimodal(n, d, seed=0):
    """The paper's §8.5 synthetic classification data (bimodal Gaussian)."""
    rng = np.random.default_rng(seed)
    n_neg = int(0.75 * n)
    n_pos = n - n_neg
    x_neg = rng.normal(10.0, np.sqrt(2.0), (n_neg, d))
    x_pos = rng.normal(30.0, np.sqrt(4.0), (n_pos, d))
    x = np.concatenate([x_neg, x_pos])
    y = np.concatenate([np.zeros((n_neg, 1)), np.ones((n_pos, 1))])
    perm = rng.permutation(n)
    # standardize: keeps Newton well-conditioned, same as the Rust driver
    x = (x - x.mean(0)) / x.std(0)
    return jnp.asarray(x[perm]), jnp.asarray(y[perm])


def test_newton_loss_decreases():
    x, y = _bimodal(512, 8)
    _, losses = model.newton_solve_ref(x, y, steps=8)
    assert len(losses) >= 3
    assert losses[-1] < losses[0] * 0.1, losses


def test_newton_reaches_high_accuracy():
    x, y = _bimodal(1024, 4, seed=1)
    beta, _ = model.newton_solve_ref(x, y, steps=12)
    mu = ref.glm_mu(x, beta)
    acc = float(jnp.mean(((mu > 0.5).astype(jnp.float64) == y)))
    assert acc > 0.97, acc


def test_gradient_matches_finite_difference():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 5)))
    y = jnp.asarray(rng.integers(0, 2, (64, 1)), dtype=jnp.float64)
    beta = jnp.asarray(0.1 * rng.standard_normal((5, 1)))
    g, _, _ = ref.newton_block(x, y, beta)
    eps = 1e-6
    for i in range(5):
        e = jnp.zeros((5, 1)).at[i, 0].set(eps)
        lp = model.logistic_loss_ref(x, y, beta + e)
        lm = model.logistic_loss_ref(x, y, beta - e)
        fd = (lp - lm) / (2 * eps)
        np.testing.assert_allclose(float(g[i, 0]), fd, rtol=1e-4, atol=1e-6)


def test_hessian_matches_finite_difference_of_gradient():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 4)))
    y = jnp.asarray(rng.integers(0, 2, (64, 1)), dtype=jnp.float64)
    beta = jnp.asarray(0.1 * rng.standard_normal((4, 1)))
    _, h, _ = ref.newton_block(x, y, beta)
    eps = 1e-6
    for i in range(4):
        e = jnp.zeros((4, 1)).at[i, 0].set(eps)
        gp, _, _ = ref.newton_block(x, y, beta + e)
        gm, _, _ = ref.newton_block(x, y, beta - e)
        fd_col = (gp - gm) / (2 * eps)
        np.testing.assert_allclose(np.asarray(h[:, i : i + 1]), np.asarray(fd_col), rtol=1e-4, atol=1e-6)


def test_predict_block_matches_mu():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((128, 8)))
    beta = jnp.asarray(0.2 * rng.standard_normal((8, 1)))
    np.testing.assert_allclose(model.predict_block(x, beta), ref.glm_mu(x, beta), rtol=1e-10)
