"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-aligned sizes, which exercise
the divisor-tiling fallback) and dtypes (f32/f64).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.matmul import _tile

DIMS = st.integers(min_value=1, max_value=97)
DTYPES = st.sampled_from([jnp.float32, jnp.float64])


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(rtol=1e-11, atol=1e-11)


# ---------------- tiling helper ----------------


@given(st.integers(1, 10_000), st.integers(1, 512))
def test_tile_divides_and_bounded(n, target):
    t = _tile(n, target)
    assert 1 <= t <= min(n, target)
    assert n % t == 0


def test_tile_exact():
    assert _tile(256, 128) == 128
    assert _tile(97, 128) == 97  # prime: whole extent
    assert _tile(96, 64) == 48


# ---------------- element-wise ----------------


@given(
    name=st.sampled_from(["add", "sub", "mul", "div"]),
    m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31),
)
def test_binary_ew(name, m, n, dtype, seed):
    x = _rand((m, n), dtype, seed)
    y = _rand((m, n), dtype, seed + 1)
    if name == "div":
        y = y + jnp.sign(y) * 1.0 + (y == 0) * 1.0  # keep away from 0
    got = getattr(kernels, name)(x, y)
    want = getattr(ref, name)(x, y)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(got, want, **_tol(dtype))


@given(
    name=st.sampled_from(["neg", "sigmoid"]),
    m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31),
)
def test_unary_ew(name, m, n, dtype, seed):
    x = _rand((m, n), dtype, seed)
    got = getattr(kernels, name)(x)
    want = getattr(ref, name)(x)
    np.testing.assert_allclose(got, want, **_tol(dtype))


# ---------------- contractions ----------------


@given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_matmul(m, k, n, dtype, seed):
    x = _rand((m, k), dtype, seed)
    y = _rand((k, n), dtype, seed + 1)
    np.testing.assert_allclose(kernels.matmul(x, y), ref.matmul(x, y), **_tol(dtype))


@given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_matmul_nt(m, k, n, dtype, seed):
    x = _rand((m, k), dtype, seed)
    y = _rand((n, k), dtype, seed + 1)
    np.testing.assert_allclose(kernels.matmul_nt(x, y), ref.matmul_nt(x, y), **_tol(dtype))


@given(k=DIMS, m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_gram(k, m, n, dtype, seed):
    x = _rand((k, m), dtype, seed)
    y = _rand((k, n), dtype, seed + 1)
    np.testing.assert_allclose(kernels.gram(x, y), ref.gram(x, y), **_tol(dtype))


def test_matmul_tile_sweep():
    """Explicit tile-size ablation: result must not depend on tiling."""
    x = _rand((96, 96), jnp.float64, 7)
    y = _rand((96, 96), jnp.float64, 8)
    want = ref.matmul(x, y)
    for b in (8, 16, 32, 48, 96, 128):
        np.testing.assert_allclose(
            kernels.matmul(x, y, bm=b, bk=b, bn=b), want, rtol=1e-11
        )


# ---------------- reductions ----------------


@given(
    name=st.sampled_from(["sum_axis0", "sum_axis1", "sum_all"]),
    m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31),
)
def test_reductions(name, m, n, dtype, seed):
    x = _rand((m, n), dtype, seed)
    got = getattr(kernels, name)(x)
    want = getattr(ref, name)(x)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_sum_shapes():
    x = jnp.ones((5, 7), dtype=jnp.float64)
    assert kernels.sum_axis0(x).shape == (1, 7)
    assert kernels.sum_axis1(x).shape == (5, 1)
    assert kernels.sum_all(x).shape == (1, 1)
    np.testing.assert_allclose(kernels.sum_all(x)[0, 0], 35.0)
