import jax

jax.config.update("jax_enable_x64", True)

from hypothesis import settings

# Pallas interpret mode is slow; keep example counts modest but meaningful.
settings.register_profile("nums", max_examples=20, deadline=None)
settings.load_profile("nums")
