"""AOT pipeline: specs lower to parseable HLO text, manifest is consistent."""

import os
import subprocess
import sys

import pytest

from compile import aot, specs


def test_every_spec_has_builder():
    for name, dims, _ in specs.SPECS:
        assert name in aot.BUILDERS, name


def test_spec_keys_unique():
    keys = [specs.key(n, d) for n, d, _ in specs.SPECS]
    assert len(keys) == len(set(keys))


@pytest.mark.parametrize(
    "name,dims,n_out",
    [
        ("add", (64, 64), 1),
        ("matmul", (64, 64, 64), 1),
        ("gram", (2048, 16, 16), 1),
        ("newton_block", (512, 8), 3),
        ("lbfgs_block", (512, 8), 2),
    ],
)
def test_lower_one(name, dims, n_out):
    text, in_dims, out_shapes = aot.lower_spec(name, dims)
    assert text.startswith("HloModule")
    assert "f64" in text
    assert len(out_shapes) == n_out
    # GLM fused blocks: X, y, beta inputs
    if name == "newton_block":
        assert in_dims == [(512, 8), (512, 1), (8, 1)]
        assert out_shapes == [(8, 1), (8, 8), (1, 1)]


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--only", "neg,sum_all"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.tsv").read_text().strip().splitlines()
    rows = [l for l in manifest if not l.startswith("#")]
    want = [s for s in specs.SPECS if s[0] in ("neg", "sum_all")]
    assert len(rows) == len(want)
    for row in rows:
        name, dims, fname, n_out, in_shapes, out_shapes = row.split("\t")
        assert (out / fname).exists()
        assert (out / fname).read_text().startswith("HloModule")
        assert int(n_out) == len(out_shapes.split(";"))


def test_manifest_dims_parse_roundtrip():
    for name, dims, n_out in specs.SPECS:
        s = "x".join(str(d) for d in dims)
        assert tuple(int(t) for t in s.split("x")) == dims
