"""Fused GLM kernels (L1) and the composed newton/lbfgs blocks (L2) vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import kernels, model
from compile.kernels import ref

M = st.integers(min_value=2, max_value=300)
D = st.integers(min_value=1, max_value=24)
DTYPES = st.sampled_from([jnp.float32, jnp.float64])


def _tol(dtype):
    return dict(rtol=3e-4, atol=3e-5) if dtype == jnp.float32 else dict(rtol=1e-9, atol=1e-11)


def _data(m, d, dtype, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, d)), dtype=dtype)
    y = jnp.asarray(rng.integers(0, 2, (m, 1)), dtype=dtype)
    beta = jnp.asarray(0.1 * rng.standard_normal((d, 1)), dtype=dtype)
    return x, y, beta


@given(m=M, d=D, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_glm_mu(m, d, dtype, seed):
    x, _, beta = _data(m, d, dtype, seed)
    got = kernels.glm_mu(x, beta)
    want = ref.glm_mu(x, beta)
    assert got.shape == (m, 1)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    assert bool(jnp.all((got > 0) & (got < 1)))


@given(m=M, d=D, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_glm_grad(m, d, dtype, seed):
    x, y, beta = _data(m, d, dtype, seed)
    mu = ref.glm_mu(x, beta)
    np.testing.assert_allclose(
        kernels.glm_grad(x, mu, y), ref.glm_grad(x, mu, y), **_tol(dtype)
    )


@given(m=M, d=D, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_glm_hess(m, d, dtype, seed):
    x, _, beta = _data(m, d, dtype, seed)
    mu = ref.glm_mu(x, beta)
    got = kernels.glm_hess(x, mu)
    want = ref.glm_hess(x, mu)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    # Hessian of a convex objective: symmetric PSD.
    np.testing.assert_allclose(got, got.T, **_tol(dtype))
    eig = np.linalg.eigvalsh(np.asarray(want, dtype=np.float64))
    assert eig.min() >= -1e-6


@given(m=M, d=D, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_logloss(m, d, dtype, seed):
    x, y, beta = _data(m, d, dtype, seed)
    mu = ref.glm_mu(x, beta)
    got = kernels.logloss(mu, y)
    want = ref.logloss(mu, y)
    assert got.shape == (1, 1)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    assert float(got[0, 0]) >= 0.0


@given(m=M, d=D, seed=st.integers(0, 2**31))
def test_newton_block_composed(m, d, seed):
    x, y, beta = _data(m, d, jnp.float64, seed)
    g, h, loss = model.newton_block(x, y, beta)
    g2, h2, loss2 = model.newton_block_ref(x, y, beta)
    np.testing.assert_allclose(g, g2, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(h, h2, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(loss, loss2, rtol=1e-9, atol=1e-11)


@given(m=M, d=D, seed=st.integers(0, 2**31))
def test_lbfgs_block_composed(m, d, seed):
    x, y, beta = _data(m, d, jnp.float64, seed)
    g, loss = model.lbfgs_block(x, y, beta)
    g2, loss2 = model.lbfgs_block_ref(x, y, beta)
    np.testing.assert_allclose(g, g2, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(loss, loss2, rtol=1e-9, atol=1e-11)


def test_blockwise_additivity():
    """g/H/loss of a stacked dataset == sum of per-block contributions.

    This is the invariant the Rust coordinator's Reduce tree relies on.
    """
    rng = np.random.default_rng(0)
    d = 6
    xs = [jnp.asarray(rng.standard_normal((m, d))) for m in (32, 48, 80)]
    ys = [jnp.asarray(rng.integers(0, 2, (m, 1)), dtype=jnp.float64) for m in (32, 48, 80)]
    beta = jnp.asarray(0.05 * rng.standard_normal((d, 1)))
    x_full, y_full = jnp.concatenate(xs), jnp.concatenate(ys)
    g_full, h_full, l_full = model.newton_block_ref(x_full, y_full, beta)
    parts = [model.newton_block(x, y, beta) for x, y in zip(xs, ys)]
    g_sum = sum(p[0] for p in parts)
    h_sum = sum(p[1] for p in parts)
    l_sum = sum(p[2] for p in parts)
    np.testing.assert_allclose(g_sum, g_full, rtol=1e-9)
    np.testing.assert_allclose(h_sum, h_full, rtol=1e-9)
    np.testing.assert_allclose(l_sum, l_full, rtol=1e-9)


@pytest.mark.parametrize("bm", [16, 64, 256, 512])
def test_glm_tile_invariance(bm):
    x, y, beta = _data(256, 8, jnp.float64, 3)
    mu = ref.glm_mu(x, beta)
    np.testing.assert_allclose(kernels.glm_grad(x, mu, y, bm=bm), ref.glm_grad(x, mu, y), rtol=1e-10)
    np.testing.assert_allclose(kernels.glm_hess(x, mu, bm=bm), ref.glm_hess(x, mu), rtol=1e-10)
