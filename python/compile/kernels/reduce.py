"""Axis-reduction Pallas kernels.

Block bodies of the paper's ``ReduceAxis(add, X, axis)`` vertex (Fig. 5c):
each block reduces locally, then the Rust coordinator sums the per-block
outputs with a locality-paired ``Reduce`` tree (§4) using the ``add`` kernel.
Outputs keep a 2-D shape ((1, n), (m, 1), (1, 1)) so that reduce trees reuse
the same block layout everywhere.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _tile


def _sum0_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], axis=0, keepdims=True)


def sum_axis0(x, *, bm: int = 256, bn: int = 256):
    """(m, n) -> (1, n), summing over rows."""
    m, n = x.shape
    bm_, bn_ = _tile(m, bm), _tile(n, bn)
    return pl.pallas_call(
        _sum0_kernel,
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        grid=(n // bn_, m // bm_),
        in_specs=[pl.BlockSpec((bm_, bn_), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, bn_), lambda j, i: (0, j)),
        interpret=True,
    )(x)


def _sum1_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], axis=1, keepdims=True)


def sum_axis1(x, *, bm: int = 256, bn: int = 256):
    """(m, n) -> (m, 1), summing over columns."""
    m, n = x.shape
    bm_, bn_ = _tile(m, bm), _tile(n, bn)
    return pl.pallas_call(
        _sum1_kernel,
        out_shape=jax.ShapeDtypeStruct((m, 1), x.dtype),
        grid=(m // bm_, n // bn_),
        in_specs=[pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm_, 1), lambda i, j: (i, 0)),
        interpret=True,
    )(x)


def _sumall_kernel(x_ref, o_ref):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], keepdims=True)


def sum_all(x, *, bm: int = 256, bn: int = 256):
    """(m, n) -> (1, 1), full reduction."""
    m, n = x.shape
    bm_, bn_ = _tile(m, bm), _tile(n, bn)
    return pl.pallas_call(
        _sumall_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        grid=(m // bm_, n // bn_),
        in_specs=[pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        interpret=True,
    )(x)
