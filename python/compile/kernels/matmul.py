"""Tiled Pallas matmul kernels: the block-level BLAS substrate.

The paper's per-worker compute is a single-threaded BLAS call on a dense
block.  On the TPU-shaped L1 we express that call as a Pallas kernel whose
``BlockSpec`` grid streams (bm, bk) x (bk, bn) tiles HBM->VMEM and
accumulates in the output tile, i.e. the MXU-systolic mapping of a blocked
GEMM.  Three variants cover the paper's §8.1 microbenchmarks:

* ``matmul``     C = A @ B            (square DGEMM, Fig. 10)
* ``matmul_nt``  C = A @ B^T          (block-wise outer product, App. A.4)
* ``gram``       C = A^T @ B          (block-wise inner product, App. A.3 —
                                       the Hessian hot-spot of §6)

Transpose never materializes: it is fused into the contraction, which is
exactly the paper's "transpose is executed lazily by fusing with the next
operation" rule (§6).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= target (>=1)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ y_ref[...]


def matmul(x, y, *, bm: int = 128, bk: int = 128, bn: int = 128):
    """C[m,n] = A[m,k] @ B[k,n] as a tiled Pallas kernel."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm, bk, bn = _tile(m, bm), _tile(k, bk), _tile(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        interpret=True,
    )(x, y)


def _mm_nt_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ y_ref[...].T


def matmul_nt(x, y, *, bm: int = 128, bk: int = 128, bn: int = 128):
    """C[m,n] = A[m,k] @ B[n,k]^T — fused-transpose outer-product block."""
    m, k = x.shape
    n, k2 = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}^T"
    bm, bk, bn = _tile(m, bm), _tile(k, bk), _tile(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_nt_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bn, bk), lambda i, j, h: (j, h)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        interpret=True,
    )(x, y)


def _gram_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].T @ y_ref[...]


def gram(x, y, *, bm: int = 128, bk: int = 512, bn: int = 128):
    """C[m,n] = A[k,m]^T @ B[k,n] — fused-transpose inner-product block.

    This is the most expensive operation of the GLM Hessian (§6 / App. A.3):
    the reduction dimension k is the tall axis, so it is the grid's innermost
    loop and the (m, n) output tile stays resident in VMEM.
    """
    k, m = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape}^T @ {y.shape}"
    bm, bk, bn = _tile(m, bm), _tile(k, bk), _tile(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gram_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, h: (h, i)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        interpret=True,
    )(x, y)
