"""Layer-1 Pallas kernels (build-time only).

Every kernel here is the per-block compute hot-spot of the NumS
reproduction: the Rust coordinator (L3) schedules *blocks* of distributed
arrays onto simulated cluster nodes, and each block-level task executes one
of these kernels through the PJRT runtime, using HLO artifacts lowered by
``compile.aot``.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO ops
that any backend (including the Rust-side PJRT CPU client) can run.  Tiling
choices (128-aligned tiles, VMEM-resident accumulators) still reflect the
TPU mapping documented in DESIGN.md §Hardware-Adaptation.

Blocks are f64 to match the Rust coordinator's block storage.
"""

import jax

# Must happen before any tracing; the whole stack is f64.
jax.config.update("jax_enable_x64", True)

from .matmul import matmul, matmul_nt, gram  # noqa: E402,F401
from .ew import add, sub, mul, div, neg, sigmoid  # noqa: E402,F401
from .reduce import sum_axis0, sum_axis1, sum_all  # noqa: E402,F401
from .glm import glm_mu, glm_grad, glm_hess, logloss  # noqa: E402,F401
