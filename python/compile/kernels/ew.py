"""Element-wise Pallas kernels.

These are the block-level bodies of the paper's unary / binary element-wise
GraphArray operations (Table 1, Fig. 5a/5b).  LSHS schedules them with zero
communication (App. A.1); the compute itself is a trivially tiled VPU map.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _tile


def _ew2(fn):
    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = fn(x_ref[...], y_ref[...])

    def call(x, y, *, bm: int = 256, bn: int = 256):
        assert x.shape == y.shape, f"ew shape mismatch {x.shape} vs {y.shape}"
        m, n = x.shape
        bm_, bn_ = _tile(m, bm), _tile(n, bn)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            grid=(m // bm_, n // bn_),
            in_specs=[
                pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
                pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            interpret=True,
        )(x, y)

    return call


def _ew1(fn):
    def kernel(x_ref, o_ref):
        o_ref[...] = fn(x_ref[...])

    def call(x, *, bm: int = 256, bn: int = 256):
        m, n = x.shape
        bm_, bn_ = _tile(m, bm), _tile(n, bn)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            grid=(m // bm_, n // bn_),
            in_specs=[pl.BlockSpec((bm_, bn_), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            interpret=True,
        )(x)

    return call


add = _ew2(jnp.add)
sub = _ew2(jnp.subtract)
mul = _ew2(jnp.multiply)
div = _ew2(jnp.divide)
neg = _ew1(jnp.negative)
sigmoid = _ew1(lambda v: 1.0 / (1.0 + jnp.exp(-v)))
