"""Pure-jnp oracle for every Pallas kernel.

This module is the correctness contract of L1: ``pytest python/tests``
asserts ``assert_allclose(kernel(x), ref.kernel(x))`` over hypothesis-swept
shapes and dtypes.  Nothing here is ever lowered into artifacts.
"""

import jax.numpy as jnp

_EPS = 1e-12


def matmul(x, y):
    return x @ y


def matmul_nt(x, y):
    return x @ y.T


def gram(x, y):
    return x.T @ y


def add(x, y):
    return x + y


def sub(x, y):
    return x - y


def mul(x, y):
    return x * y


def div(x, y):
    return x / y


def neg(x):
    return -x


def sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def sum_axis0(x):
    return jnp.sum(x, axis=0, keepdims=True)


def sum_axis1(x):
    return jnp.sum(x, axis=1, keepdims=True)


def sum_all(x):
    return jnp.sum(x, keepdims=True).reshape(1, 1)


def glm_mu(x, beta):
    return sigmoid(x @ beta)


def glm_grad(x, mu, y):
    return x.T @ (mu - y)


def glm_hess(x, mu):
    return x.T @ ((mu * (1.0 - mu)) * x)


def logloss(mu, y):
    mu = jnp.clip(mu, _EPS, 1.0 - _EPS)
    return (-jnp.sum(y * jnp.log(mu) + (1.0 - y) * jnp.log(1.0 - mu))).reshape(1, 1)


def newton_block(x, y, beta):
    """Composed per-block Newton contribution (the L2 fusion)."""
    mu = glm_mu(x, beta)
    return glm_grad(x, mu, y), glm_hess(x, mu), logloss(mu, y)
