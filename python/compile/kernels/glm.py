"""Fused GLM block kernels (§6 of the paper).

Newton's method for logistic regression touches each block of the design
matrix X three times per iteration: the model mean mu = sigmoid(X beta), the
gradient X^T (mu - y), and the Hessian X^T diag(mu (1 - mu)) X.  The paper
fuses the lazy transpose into the contraction and keeps every element-wise
intermediate local; on the TPU-shaped L1 that becomes *kernel fusion*: each
of the three kernels streams row-tiles of X through VMEM once and never
materializes an intermediate block in HBM.

``logloss`` is the per-block negative log-likelihood used by the e2e driver
to report the loss curve.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _tile


def _mu_kernel(x_ref, beta_ref, o_ref):
    o_ref[...] = 1.0 / (1.0 + jnp.exp(-(x_ref[...] @ beta_ref[...])))


def glm_mu(x, beta, *, bm: int = 512):
    """mu[m,1] = sigmoid(X[m,d] @ beta[d,1]) — fused matvec + logistic."""
    m, d = x.shape
    assert beta.shape == (d, 1), f"beta shape {beta.shape} != ({d},1)"
    bm_ = _tile(m, bm)
    return pl.pallas_call(
        _mu_kernel,
        out_shape=jax.ShapeDtypeStruct((m, 1), x.dtype),
        grid=(m // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
        interpret=True,
    )(x, beta)


def _grad_kernel(x_ref, mu_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].T @ (mu_ref[...] - y_ref[...])


def glm_grad(x, mu, y, *, bm: int = 512):
    """g[d,1] = X^T (mu - y), accumulated over row-tiles of X."""
    m, d = x.shape
    assert mu.shape == (m, 1) and y.shape == (m, 1)
    bm_ = _tile(m, bm)
    return pl.pallas_call(
        _grad_kernel,
        out_shape=jax.ShapeDtypeStruct((d, 1), x.dtype),
        grid=(m // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d, 1), lambda i: (0, 0)),
        interpret=True,
    )(x, mu, y)


def _hess_kernel(x_ref, mu_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = mu_ref[...] * (1.0 - mu_ref[...])  # [bm, 1] diag weights
    o_ref[...] += x_ref[...].T @ (w * x_ref[...])


def glm_hess(x, mu, *, bm: int = 512):
    """H[d,d] = X^T diag(mu (1-mu)) X, accumulated over row-tiles of X."""
    m, d = x.shape
    assert mu.shape == (m, 1)
    bm_ = _tile(m, bm)
    return pl.pallas_call(
        _hess_kernel,
        out_shape=jax.ShapeDtypeStruct((d, d), x.dtype),
        grid=(m // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        interpret=True,
    )(x, mu)


_EPS = 1e-12


def _logloss_kernel(mu_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mu = jnp.clip(mu_ref[...], _EPS, 1.0 - _EPS)
    y = y_ref[...]
    o_ref[...] += -jnp.sum(y * jnp.log(mu) + (1.0 - y) * jnp.log(1.0 - mu), keepdims=True)


def logloss(mu, y, *, bm: int = 512):
    """loss[1,1] = -sum(y log mu + (1-y) log(1-mu)) over the block."""
    m, _ = mu.shape
    assert mu.shape == y.shape == (m, 1)
    bm_ = _tile(m, bm)
    return pl.pallas_call(
        _logloss_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), mu.dtype),
        grid=(m // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=True,
    )(mu, y)
