"""AOT lowering: Pallas/JAX kernels -> HLO *text* artifacts for the Rust runtime.

The interchange format is HLO TEXT, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser on the Rust side (``HloModuleProto::from_text_file``)
reassigns ids and round-trips cleanly.  See /opt/xla-example/load_hlo.

Outputs (in --out, default ../artifacts):
  <key>.hlo.txt      one module per (kernel, shape) spec, lowered with
                     return_tuple=True (Rust unwraps with to_tupleN)
  manifest.tsv       name, dims, file, n_outputs, input shapes, output shapes

Usage: cd python && python -m compile.aot --out ../artifacts [--only k1,k2]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import kernels, model, specs  # noqa: E402

DTYPE = jnp.float64


def _s(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), DTYPE)


def _tupled(fn):
    """Wrap so the lowered module always returns a tuple (Rust unwraps)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


# builder: dims -> (callable, [input ShapeDtypeStructs])
BUILDERS = {
    "add": lambda d: (kernels.add, [_s(*d), _s(*d)]),
    "sub": lambda d: (kernels.sub, [_s(*d), _s(*d)]),
    "mul": lambda d: (kernels.mul, [_s(*d), _s(*d)]),
    "div": lambda d: (kernels.div, [_s(*d), _s(*d)]),
    "neg": lambda d: (kernels.neg, [_s(*d)]),
    "sigmoid": lambda d: (kernels.sigmoid, [_s(*d)]),
    "matmul": lambda d: (kernels.matmul, [_s(d[0], d[1]), _s(d[1], d[2])]),
    "matmul_nt": lambda d: (kernels.matmul_nt, [_s(d[0], d[1]), _s(d[2], d[1])]),
    "gram": lambda d: (kernels.gram, [_s(d[0], d[1]), _s(d[0], d[2])]),
    "sum_axis0": lambda d: (kernels.sum_axis0, [_s(*d)]),
    "sum_axis1": lambda d: (kernels.sum_axis1, [_s(*d)]),
    "sum_all": lambda d: (kernels.sum_all, [_s(*d)]),
    "glm_mu": lambda d: (kernels.glm_mu, [_s(d[0], d[1]), _s(d[1], 1)]),
    "glm_grad": lambda d: (kernels.glm_grad, [_s(d[0], d[1]), _s(d[0], 1), _s(d[0], 1)]),
    "glm_hess": lambda d: (kernels.glm_hess, [_s(d[0], d[1]), _s(d[0], 1)]),
    "logloss": lambda d: (kernels.logloss, [_s(d[0], 1), _s(d[0], 1)]),
    "newton_block": lambda d: (model.newton_block, [_s(d[0], d[1]), _s(d[0], 1), _s(d[1], 1)]),
    "lbfgs_block": lambda d: (model.lbfgs_block, [_s(d[0], d[1]), _s(d[0], 1), _s(d[1], 1)]),
    "predict_block": lambda d: (model.predict_block, [_s(d[0], d[1]), _s(d[1], 1)]),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(name, dims):
    fn, in_shapes = BUILDERS[name](dims)
    lowered = jax.jit(_tupled(fn)).lower(*in_shapes)
    out_avals = lowered.out_info
    out_shapes = [tuple(int(x) for x in o.shape) for o in jax.tree_util.tree_leaves(out_avals)]
    in_dims = [tuple(int(x) for x in s.shape) for s in in_shapes]
    return to_hlo_text(lowered), in_dims, out_shapes


def fmt_shapes(shapes) -> str:
    return ";".join("x".join(str(d) for d in s) for s in shapes)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated kernel names to lower")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    os.makedirs(args.out, exist_ok=True)
    rows = []
    for name, dims, n_out in specs.SPECS:
        if only and name not in only:
            continue
        key = specs.key(name, dims)
        fname = f"{key}.hlo.txt"
        text, in_dims, out_shapes = lower_spec(name, dims)
        assert len(out_shapes) == n_out, (
            f"{key}: spec says {n_out} outputs, lowering produced {len(out_shapes)}"
        )
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        rows.append(
            "\t".join(
                [
                    name,
                    "x".join(str(d) for d in dims),
                    fname,
                    str(n_out),
                    fmt_shapes(in_dims),
                    fmt_shapes(out_shapes),
                ]
            )
        )
        print(f"  lowered {key:28s} -> {fname} ({len(text)} chars)", file=sys.stderr)

    manifest = os.path.join(args.out, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# name\tdims\tfile\tn_outputs\tinput_shapes\toutput_shapes\n")
        f.write("\n".join(rows) + "\n")
    print(f"wrote {len(rows)} artifacts + {manifest}", file=sys.stderr)


if __name__ == "__main__":
    main()
