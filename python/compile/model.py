"""Layer-2 JAX compute graphs.

NumS's "model" is the generalized linear model of §6: the per-block pieces
of a Newton iteration, composed from the L1 Pallas kernels so that one RFC
(one PJRT execution on the Rust side) covers what would otherwise be three
to five block-level tasks.  This is exactly the "operator fusion" the
paper's §9 lists as future work for reducing RFC overhead — here it is a
first-class artifact.

Everything in this module is lowered ONCE by ``compile.aot`` to HLO text;
Python never runs on the request path.
"""

import jax.numpy as jnp

from . import kernels
from .kernels import ref


def newton_block(x, y, beta):
    """Fused per-block Newton contribution.

    Inputs:  X[m,d] block, y[m,1] block, beta[d,1] (broadcast by L3).
    Outputs: (g[d,1], H[d,d], loss[1,1]) — the block's additive
    contributions, reduced across blocks by the coordinator's locality-aware
    Reduce tree.
    """
    mu = kernels.glm_mu(x, beta)
    g = kernels.glm_grad(x, mu, y)
    h = kernels.glm_hess(x, mu)
    loss = kernels.logloss(mu, y)
    return g, h, loss


def lbfgs_block(x, y, beta):
    """Fused per-block gradient + loss for first-order optimizers (§8.5).

    L-BFGS (the Spark MLlib comparison) needs only (g, loss) per block.
    """
    mu = kernels.glm_mu(x, beta)
    g = kernels.glm_grad(x, mu, y)
    loss = kernels.logloss(mu, y)
    return g, loss


def predict_block(x, beta):
    """Per-block prediction: class probabilities, thresholded by the caller."""
    return kernels.glm_mu(x, beta)


def newton_block_ref(x, y, beta):
    """Pure-jnp oracle of ``newton_block`` (used by pytest only)."""
    return ref.newton_block(x, y, beta)


def lbfgs_block_ref(x, y, beta):
    mu = ref.glm_mu(x, beta)
    return ref.glm_grad(x, mu, y), ref.logloss(mu, y)


def logistic_loss_ref(x, y, beta):
    """Whole-dataset reference loss, for convergence tests."""
    mu = ref.glm_mu(x, beta)
    return float(ref.logloss(mu, y)[0, 0])


def newton_solve_ref(x, y, steps: int = 10, eps: float = 1e-8):
    """Dense single-node Newton reference (Algorithm 2), for tests.

    Mirrors the Rust coordinator's distributed loop: same updates, same
    convergence test, no regularizer.
    """
    n, d = x.shape
    beta = jnp.zeros((d, 1), dtype=x.dtype)
    losses = []
    for _ in range(steps):
        g, h, loss = ref.newton_block(x, y, beta)
        losses.append(float(loss[0, 0]))
        beta = beta - jnp.linalg.solve(h + 1e-10 * jnp.eye(d, dtype=x.dtype), g)
        if float(jnp.linalg.norm(g)) <= eps:
            break
    return beta, losses
