"""Canonical AOT kernel/shape manifest — single source of truth.

HLO is shape-monomorphic, so the Rust runtime can only run (kernel, shape)
pairs that were lowered at build time.  This list is mirrored by
``rust/src/kernels/shapes.rs``; the Rust `NativeBackend` covers everything
else.  Keep the two in sync: `python/tests/test_specs.py` and the Rust test
`kernels::shapes::tests` both parse this file's emitted manifest.

Spec format: (name, dims, n_outputs) where ``dims`` parameterizes the
builder in ``aot.BUILDERS``:

* ew / neg / sigmoid:      (m, n)          1 in/2 in -> (m, n)
* matmul:                  (m, k, n)       A[m,k] @ B[k,n]
* matmul_nt:               (m, k, n)       A[m,k] @ B[n,k]^T
* gram:                    (k, m, n)       A[k,m]^T @ B[k,n]
* sum_axis0 / sum_axis1 / sum_all: (m, n)
* glm_mu:                  (m, d)          + beta[d,1]
* glm_grad / glm_hess / logloss:   (m, d)
* newton_block / lbfgs_block:      (m, d)  fused L2 composites
"""

# GLM block geometries used by the e2e example, tests and benches.
GLM_SHAPES = [(512, 8), (2048, 16), (4096, 32)]

# Square DGEMM block sizes (Fig. 10 scaled) + a rectangular case.
MM_SHAPES = [(64, 64, 64), (128, 128, 128), (256, 256, 256)]

SPECS = []


def _add(name, dims, n_out=1):
    SPECS.append((name, tuple(int(d) for d in dims), n_out))


# --- element-wise (reduce-tree `add` shapes included) ---
for shape in [(256, 256), (64, 64)]:
    for op in ("add", "sub", "mul", "div", "neg", "sigmoid"):
        _add(op, shape)
# reduce-tree shapes for GLM outputs: g[d,1], H[d,d], loss[1,1], mu[m,1]
for d in (8, 16, 32):
    _add("add", (d, 1))
    _add("add", (d, d))
for m in (512, 2048, 4096):
    _add("add", (m, 1))
_add("add", (1, 1))

# --- contractions ---
for dims in MM_SHAPES:
    _add("matmul", dims)
    _add("matmul_nt", dims)
_add("gram", (2048, 16, 16))
_add("gram", (4096, 32, 32))
_add("gram", (2048, 16, 1))   # X^T c matvec (gradient shape)
_add("gram", (4096, 32, 1))
_add("matmul", (256, 256, 1))  # matvec X @ y (Fig. 9)

# --- reductions ---
_add("sum_axis0", (256, 256))
_add("sum_axis1", (256, 256))
_add("sum_all", (256, 256))

# --- GLM fused kernels + L2 composites ---
for m, d in GLM_SHAPES:
    _add("glm_mu", (m, d))
    _add("glm_grad", (m, d))
    _add("glm_hess", (m, d))
    _add("logloss", (m, d))
    _add("newton_block", (m, d), n_out=3)
    _add("lbfgs_block", (m, d), n_out=2)
    _add("predict_block", (m, d))


def key(name, dims) -> str:
    return f"{name}_{'x'.join(str(d) for d in dims)}"
